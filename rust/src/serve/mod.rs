//! Network serving tier — the wire-ready front door over the coordinator.
//!
//! This module turns the in-process serving stack (router/batcher +
//! engine or cluster backend) into a deployable network service without
//! adding a single dependency:
//!
//! * [`error`] — [`ServeError`]: the canonical serving error with stable
//!   wire codes, shared by every layer from request validation to the
//!   socket (no more `Result<_, String>` plumbing).
//! * [`proto`] — the length-prefixed binary protocol: a 20-byte versioned
//!   frame header, typed request/response/error frames, f32 payloads by
//!   bit pattern so wire responses can be compared bit-exactly against
//!   in-process serving.
//! * [`http`]  — a minimal HTTP/1.1 shim on the same port (`POST
//!   /v1/classify`, `GET /metrics`, `GET /healthz`, `GET /admin/drain`)
//!   so `curl` and load-balancer probes work out of the box.  The
//!   protocol is sniffed from the first byte: `B` (the frame magic)
//!   selects binary, anything else HTTP — no HTTP method starts with `B`.
//! * [`conn`]  — per-connection handling: pipelined binary reads with a
//!   per-connection writer that answers in request order, poll-tick
//!   reads so drains are noticed promptly.
//!
//! On top of those sit the deployment-level types:
//!
//! * [`ServeConfig`] / [`ServeConfig::builder`] — ONE config for the
//!   whole stack (engine knobs, batcher knobs, network knobs) with
//!   builder > environment > default precedence.
//! * [`Deployment`] — the backend selector: a single shared [`Engine`]
//!   or a sharded `ClusterRouter`, chosen by the config exactly like the
//!   CLI used to, behind one [`InferenceBackend`] face.  Owns snapshot
//!   load/save so every frontend gets persistence for free.
//! * [`serve_deployment`] — the in-process frontend: the same
//!   router/batcher `serve_engine` uses, over a `Deployment`.
//! * [`NetServer`] — the TCP frontend: bounded-thread-pool connection
//!   handling, per-request timeouts, graceful drain on shutdown (stop
//!   accepting, flush in-flight batches, then exit).
//! * [`WireClient`] — a tiny blocking client for the binary protocol
//!   (tests, smoke checks, CLI tooling).

pub mod conn;
pub mod error;
pub mod http;
pub mod proto;

pub use error::ServeError;
pub use proto::{Frame, WireResponse};

use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::cluster::router::shards_from_env;
use crate::cluster::snapshot::{self, SnapshotReport};
use crate::cluster::{ClusterRouter, MemoConfig};
use crate::coordinator::engine::{default_workers, Engine, EngineConfig, SeedSchedule};
use crate::coordinator::metrics::MetricsSummary;
use crate::coordinator::plan::InferenceMethod;
use crate::coordinator::server::{serve, InferenceBackend, ServerConfig, ServerHandle};
use crate::nn::bnn::{BnnModel, Method};
use crate::nn::dmcache::CacheConfig;
use crate::nn::plan::LogitBatch;

use conn::ConnShared;
use proto::ReadOutcome;

/// How often the accept loop polls its listener (it runs non-blocking so
/// shutdown is never stuck in `accept`).
const ACCEPT_TICK: Duration = Duration::from_millis(5);

/// Network-frontend tuning knobs.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// `host:port` to listen on (`None` = no network frontend).  Port 0
    /// asks the OS for a free port — read it back via
    /// [`NetServer::local_addr`].
    pub listen: Option<String>,
    /// Connection-handling pool threads = max concurrent connections.
    pub conn_threads: usize,
    /// Accepted connections queued for a pool slot before new arrivals
    /// are rejected with `503 / Overloaded`.
    pub pending_conns: usize,
    /// Deadline for completing one frame / HTTP request once its first
    /// byte arrives (idle keep-alive time is unlimited).
    pub io_timeout: Duration,
    /// End-to-end deadline for answering one classify request.
    pub request_timeout: Duration,
    /// Per-frame payload cap (also the HTTP body cap).
    pub max_frame_bytes: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            listen: None,
            conn_threads: 8,
            pending_conns: 64,
            io_timeout: Duration::from_secs(10),
            request_timeout: Duration::from_secs(30),
            max_frame_bytes: proto::MAX_FRAME_PAYLOAD,
        }
    }
}

/// One config for the whole serving stack — engine, batcher, network.
///
/// Build through [`ServeConfig::builder`], which resolves every unset
/// knob with **builder > environment > default** precedence (the
/// environment toggles are `BAYESDM_CACHE_MB`, `BAYESDM_SHARDS` and
/// `BAYESDM_MEMO_MB`, exactly the ones the engine defaults honor).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub engine: EngineConfig,
    pub server: ServerConfig,
    pub net: NetConfig,
}

impl ServeConfig {
    pub fn builder() -> ServeConfigBuilder {
        ServeConfigBuilder::default()
    }
}

/// Builder for [`ServeConfig`]; every knob is optional.  Validation
/// happens in [`ServeConfigBuilder::build`] and returns
/// [`ServeError::BadRequest`] instead of panicking deep in an engine
/// assert.
#[derive(Debug, Clone, Default)]
pub struct ServeConfigBuilder {
    workers: Option<usize>,
    seed: Option<u64>,
    cache_mb: Option<usize>,
    seed_schedule: Option<SeedSchedule>,
    alpha: Option<f64>,
    shards: Option<usize>,
    memo_mb: Option<usize>,
    snapshot: Option<String>,
    sparse_threshold: Option<f32>,
    max_batch: Option<usize>,
    max_wait: Option<Duration>,
    dispatch_workers: Option<usize>,
    queue_depth: Option<usize>,
    deadline_ms: Option<u64>,
    listen: Option<String>,
    conn_threads: Option<usize>,
    pending_conns: Option<usize>,
    io_timeout: Option<Duration>,
    request_timeout: Option<Duration>,
    max_frame_bytes: Option<usize>,
}

impl ServeConfigBuilder {
    /// Engine pool threads per batch.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = Some(n);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    /// Decomposition-cache budget in MiB; 0 disables.  Unset falls back
    /// to the `BAYESDM_CACHE_MB` environment default.
    pub fn cache_mb(mut self, mb: usize) -> Self {
        self.cache_mb = Some(mb);
        self
    }

    pub fn seed_schedule(mut self, s: SeedSchedule) -> Self {
        self.seed_schedule = Some(s);
        self
    }

    /// Fractional α of the memory-friendly sweep, in (0, 1].
    pub fn alpha(mut self, alpha: f64) -> Self {
        self.alpha = Some(alpha);
        self
    }

    /// Cluster shard count (≥ 1).  Unset falls back to `BAYESDM_SHARDS`.
    pub fn shards(mut self, n: usize) -> Self {
        self.shards = Some(n);
        self
    }

    /// Response-memo budget in MiB; 0 disables.  Unset falls back to
    /// `BAYESDM_MEMO_MB`.
    pub fn memo_mb(mut self, mb: usize) -> Self {
        self.memo_mb = Some(mb);
        self
    }

    /// Decomposition-cache snapshot path (requires the cache enabled).
    pub fn snapshot<S: Into<String>>(mut self, path: S) -> Self {
        self.snapshot = Some(path.into());
        self
    }

    /// Activation-sparsity crossover threshold (density in [0, 1]) for
    /// the engine's compiled plans; unset falls back to the
    /// `BAYESDM_SPARSE_THRESHOLD` environment toggle, then off.
    pub fn sparse_threshold(mut self, t: f32) -> Self {
        self.sparse_threshold = Some(t);
        self
    }

    /// Max requests fused into one backend dispatch.
    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = Some(n);
        self
    }

    pub fn max_wait(mut self, d: Duration) -> Self {
        self.max_wait = Some(d);
        self
    }

    /// Batch-dispatch worker threads (batches in flight at once) — NOT
    /// the engine pool.  Default 1: the engine pool is the parallelism.
    pub fn dispatch_workers(mut self, n: usize) -> Self {
        self.dispatch_workers = Some(n);
        self
    }

    pub fn queue_depth(mut self, n: usize) -> Self {
        self.queue_depth = Some(n);
        self
    }

    /// Default per-request latency budget in milliseconds.  Requests that
    /// carry no explicit deadline inherit this; `0` turns the default off
    /// (requests without a deadline never expire).
    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    /// `host:port` for the TCP frontend (port 0 = OS-assigned).
    pub fn listen<S: Into<String>>(mut self, addr: S) -> Self {
        self.listen = Some(addr.into());
        self
    }

    pub fn conn_threads(mut self, n: usize) -> Self {
        self.conn_threads = Some(n);
        self
    }

    pub fn pending_conns(mut self, n: usize) -> Self {
        self.pending_conns = Some(n);
        self
    }

    pub fn io_timeout(mut self, d: Duration) -> Self {
        self.io_timeout = Some(d);
        self
    }

    pub fn request_timeout(mut self, d: Duration) -> Self {
        self.request_timeout = Some(d);
        self
    }

    pub fn max_frame_bytes(mut self, n: usize) -> Self {
        self.max_frame_bytes = Some(n);
        self
    }

    /// Resolve every unset knob (builder > environment > default) and
    /// validate the result.
    pub fn build(self) -> Result<ServeConfig, ServeError> {
        let engine_defaults = EngineConfig::default();
        let workers = self.workers.unwrap_or_else(default_workers);
        if workers == 0 {
            return Err(ServeError::bad_request("workers must be >= 1"));
        }
        let alpha = self.alpha.unwrap_or(1.0);
        if !(alpha > 0.0 && alpha <= 1.0) {
            return Err(ServeError::bad_request(format!(
                "alpha must be in (0, 1], got {alpha}"
            )));
        }
        let cache = match self.cache_mb {
            Some(0) => CacheConfig::disabled(),
            Some(mb) => CacheConfig::with_mb(mb),
            None => CacheConfig::from_env(),
        };
        let shards = self.shards.unwrap_or_else(shards_from_env);
        if shards == 0 {
            return Err(ServeError::bad_request("shards must be >= 1"));
        }
        let memo = match self.memo_mb {
            Some(0) => MemoConfig::disabled(),
            Some(mb) => MemoConfig::with_mb(mb),
            None => MemoConfig::from_env(),
        };
        if self.snapshot.is_some() && !cache.enabled() {
            return Err(ServeError::bad_request(
                "cache snapshot requires the decomposition cache (cache_mb > 0)",
            ));
        }
        if let Some(t) = self.sparse_threshold {
            if !t.is_finite() || !(0.0..=1.0).contains(&t) {
                return Err(ServeError::bad_request("sparse threshold must be in [0, 1]"));
            }
        }
        let max_batch = self.max_batch.unwrap_or(8);
        if max_batch == 0 {
            return Err(ServeError::bad_request("max_batch must be >= 1"));
        }
        let server_defaults = ServerConfig::default();
        let server = ServerConfig {
            max_batch,
            max_wait: self.max_wait.unwrap_or(server_defaults.max_wait),
            // one dispatch worker by default: the engine pool is the
            // parallelism (see `serve_engine`'s sizing note)
            workers: self.dispatch_workers.unwrap_or(1).max(1),
            queue_depth: self.queue_depth.unwrap_or(server_defaults.queue_depth),
            deadline: match self.deadline_ms {
                Some(0) | None => server_defaults.deadline,
                Some(ms) => Some(Duration::from_millis(ms)),
            },
        };
        let engine = EngineConfig {
            workers,
            seed: self.seed.unwrap_or(engine_defaults.seed),
            cache,
            seed_schedule: self.seed_schedule.unwrap_or_default(),
            alpha,
            shards,
            memo,
            snapshot: self.snapshot,
            sparse_threshold: self.sparse_threshold.or(engine_defaults.sparse_threshold),
        };
        let net_defaults = NetConfig::default();
        let net = NetConfig {
            listen: self.listen,
            conn_threads: self.conn_threads.unwrap_or(net_defaults.conn_threads).max(1),
            pending_conns: self.pending_conns.unwrap_or(net_defaults.pending_conns).max(1),
            io_timeout: self.io_timeout.unwrap_or(net_defaults.io_timeout),
            request_timeout: self.request_timeout.unwrap_or(net_defaults.request_timeout),
            max_frame_bytes: self.max_frame_bytes.unwrap_or(net_defaults.max_frame_bytes),
        };
        Ok(ServeConfig { engine, server, net })
    }
}

enum Backend {
    Engine(Arc<Engine>),
    Cluster(Arc<ClusterRouter>),
}

/// A built serving backend: one shared engine, or a sharded cluster when
/// the config asks for shards/memoization — the deployment-shape choice
/// that used to be duplicated in every CLI arm, behind one
/// [`InferenceBackend`] face.  Owns cache-snapshot persistence: the
/// snapshot is loaded at construction and saved by
/// [`Deployment::save_snapshot`] (the cluster additionally saves on
/// drop).
pub struct Deployment {
    backend: Backend,
    snapshot: Option<String>,
    load_report: Option<SnapshotReport>,
}

impl Deployment {
    /// Build the backend `cfg` describes.  Shards > 1 or an enabled
    /// response memo select the cluster router; everything else runs the
    /// single shared engine.
    pub fn new(model: BnnModel, cfg: &ServeConfig) -> Self {
        let e = &cfg.engine;
        if e.shards > 1 || e.memo.enabled() {
            let router = Arc::new(ClusterRouter::new(model, e.clone()));
            let load_report = router.snapshot_load_report().cloned();
            Self { backend: Backend::Cluster(router), snapshot: e.snapshot.clone(), load_report }
        } else {
            let engine = Arc::new(Engine::new(model, e.clone()));
            let load_report = match (&e.snapshot, engine.cache_ref()) {
                (Some(path), Some(cache)) => {
                    Some(snapshot::load(cache, engine.model().fingerprint(), Path::new(path)))
                }
                _ => None,
            };
            Self { backend: Backend::Engine(engine), snapshot: e.snapshot.clone(), load_report }
        }
    }

    pub fn input_dim(&self) -> usize {
        match &self.backend {
            Backend::Engine(e) => e.input_dim(),
            Backend::Cluster(r) => r.input_dim(),
        }
    }

    pub fn output_dim(&self) -> usize {
        match &self.backend {
            Backend::Engine(e) => e.output_dim(),
            Backend::Cluster(r) => r.output_dim(),
        }
    }

    /// Shard count (1 for the single-engine shape).
    pub fn shards(&self) -> usize {
        match &self.backend {
            Backend::Engine(_) => 1,
            Backend::Cluster(r) => r.shards(),
        }
    }

    /// The SIMD kernel path this deployment's traffic executes with.
    pub fn kernel_isa(&self) -> &'static str {
        crate::nn::simd::isa_label()
    }

    /// What snapshot loading found at construction (`None` when no
    /// snapshot/cache is configured).
    pub fn load_report(&self) -> Option<&SnapshotReport> {
        self.load_report.as_ref()
    }

    /// Fold this backend's cache/memo/shard counters into a server
    /// summary — the single place `/metrics`, the CLI and the binary
    /// metrics frame all get their numbers from.
    pub fn fold_metrics(&self, s: &mut MetricsSummary) {
        match &self.backend {
            Backend::Engine(e) => {
                s.cache = e.cache_stats();
                s.sparsity = e.sparsity_stats();
            }
            Backend::Cluster(r) => {
                let c = r.metrics_summary();
                s.cache = c.cache;
                s.memo = c.memo;
                s.shards = c.shards;
                s.sparsity = c.sparsity;
                // fault-domain counters recorded by the cluster's own
                // supervisor, additive to the server tier's.  NOT
                // `faults_injected`: that one is process-global and the
                // server summary already carries it — adding the
                // cluster's copy would double-count.
                s.panics_caught += c.panics_caught;
                s.shard_restarts += c.shard_restarts;
            }
        }
    }

    /// Persist the decomposition cache to the configured snapshot path.
    /// `None` when no path or no cache is configured.
    pub fn save_snapshot(&self) -> Option<Result<SnapshotReport, ServeError>> {
        match &self.backend {
            Backend::Cluster(r) => r.save_snapshot(),
            Backend::Engine(e) => {
                let (path, cache) = match (&self.snapshot, e.cache_ref()) {
                    (Some(path), Some(cache)) => (path, cache),
                    _ => return None,
                };
                Some(snapshot::save(cache, e.model().fingerprint(), Path::new(path)))
            }
        }
    }

    /// Batched test-set accuracy (the `eval` driver), delegating to the
    /// backend's shared implementation.
    pub fn accuracy(&self, images: &[f32], labels: &[u8], method: &Method, batch: usize) -> f64 {
        match &self.backend {
            Backend::Engine(e) => e.accuracy(images, labels, method, batch),
            Backend::Cluster(r) => r.accuracy(images, labels, method, batch),
        }
    }
}

impl InferenceBackend for Deployment {
    fn run_batch(
        &self,
        inputs: &[Vec<f32>],
        method: &InferenceMethod,
    ) -> Result<LogitBatch, ServeError> {
        match &self.backend {
            Backend::Engine(e) => e.run_batch(inputs, method),
            Backend::Cluster(r) => r.run_batch(inputs, method),
        }
    }
}

/// Start the in-process router/batcher over a deployment — the same
/// frontend `serve_engine` provides for a bare engine, so in-process and
/// network serving share one request path.
pub fn serve_deployment(deployment: &Arc<Deployment>, cfg: ServerConfig) -> ServerHandle {
    let backend = deployment.clone();
    serve(move || Ok(backend.clone()), cfg)
}

/// The TCP front door: accept loop + bounded connection pool over one
/// [`Deployment`], speaking both wire protocols (see the module docs).
///
/// Shutdown is a graceful drain: stop accepting, wake every connection,
/// let each writer flush its in-flight replies, then stop the batcher.
pub struct NetServer {
    local_addr: SocketAddr,
    shared: Arc<ConnShared>,
    stop_accept: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    conn_workers: Vec<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `cfg.net.listen`, start the batcher and the connection pool.
    pub fn bind(deployment: Arc<Deployment>, cfg: &ServeConfig) -> Result<Self, ServeError> {
        let addr = cfg
            .net
            .listen
            .clone()
            .ok_or_else(|| ServeError::bad_request("no listen address configured"))?;
        let listener = TcpListener::bind(&addr)
            .map_err(|e| ServeError::internal(format!("bind {addr}: {e}")))?;
        let local_addr = listener
            .local_addr()
            .map_err(|e| ServeError::internal(format!("local_addr: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| ServeError::internal(format!("set_nonblocking: {e}")))?;

        let handle = serve_deployment(&deployment, cfg.server.clone());
        let shared = Arc::new(ConnShared {
            handle,
            deployment,
            request_timeout: cfg.net.request_timeout,
            io_timeout: cfg.net.io_timeout,
            max_frame: cfg.net.max_frame_bytes,
            draining: AtomicBool::new(false),
            drain_requested: AtomicBool::new(false),
        });

        let (ctx, crx) = mpsc::sync_channel::<TcpStream>(cfg.net.pending_conns);
        let crx = Arc::new(Mutex::new(crx));
        let mut conn_workers = Vec::new();
        for i in 0..cfg.net.conn_threads.max(1) {
            let crx = crx.clone();
            let shared = shared.clone();
            conn_workers.push(
                std::thread::Builder::new()
                    .name(format!("bayesdm-conn-{i}"))
                    .spawn(move || loop {
                        // lock poisoning: a sibling that panicked between
                        // recv and handle left nothing torn (the guard
                        // only covers the recv call), so recover and keep
                        // serving instead of wedging the whole pool
                        let stream = { crx.lock().unwrap_or_else(|e| e.into_inner()).recv() };
                        match stream {
                            Ok(s) => {
                                // a panicking connection handler must cost
                                // exactly one connection, never the pool
                                // thread (each would be a permanent slot
                                // leak — N panics = a dead server)
                                let caught =
                                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                        conn::handle_conn(s, &shared)
                                    }));
                                if caught.is_err() {
                                    shared.handle.metrics.record_panic_caught();
                                }
                            }
                            Err(_) => break,
                        }
                    })
                    .map_err(|e| ServeError::internal(format!("spawn conn worker: {e}")))?,
            );
        }

        let stop_accept = Arc::new(AtomicBool::new(false));
        let stop = stop_accept.clone();
        let accept_thread = std::thread::Builder::new()
            .name("bayesdm-accept".into())
            .spawn(move || {
                // `ctx` lives here: joining this thread closes the conn
                // queue, which is what lets the pool drain and exit.
                loop {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    match listener.accept() {
                        Ok((s, _peer)) => {
                            // accepted sockets must be blocking regardless
                            // of what they inherit from the listener
                            if s.set_nonblocking(false).is_err() {
                                continue;
                            }
                            if crate::trace::armed() {
                                crate::trace::emit(crate::trace::EventId::ConnAccept, 0, 0, 0);
                            }
                            match ctx.try_send(s) {
                                Ok(()) => {}
                                Err(TrySendError::Full(s)) => reject_overloaded(s),
                                Err(TrySendError::Disconnected(_)) => break,
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                        Err(_) => std::thread::sleep(ACCEPT_TICK),
                    }
                }
            })
            .map_err(|e| ServeError::internal(format!("spawn accept loop: {e}")))?;

        Ok(Self {
            local_addr,
            shared,
            stop_accept,
            accept_thread: Some(accept_thread),
            conn_workers,
        })
    }

    /// The bound address (resolves port 0 to the OS-assigned port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Whether a client asked for a drain (`GET /admin/drain`) — the
    /// host loop polls this to decide when to call
    /// [`NetServer::shutdown`].
    pub fn drain_requested(&self) -> bool {
        self.shared.drain_requested.load(Ordering::SeqCst)
    }

    /// Current metrics with the deployment's counters folded in.
    pub fn metrics_summary(&self) -> MetricsSummary {
        self.shared.metrics_summary()
    }

    /// Graceful drain: stop accepting, wake every connection, flush each
    /// connection's in-flight replies, stop the batcher.  Returns the
    /// final metrics summary.
    pub fn shutdown(mut self) -> MetricsSummary {
        self.stop_accept.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join(); // drops the conn queue sender
        }
        // Connections stop reading at the next poll tick; their writer
        // threads drain queued replies before each connection closes.
        self.shared.draining.store(true, Ordering::SeqCst);
        for w in self.conn_workers.drain(..) {
            let _ = w.join();
        }
        let summary = self.shared.metrics_summary();
        let NetServer { shared, .. } = self;
        if let Ok(s) = Arc::try_unwrap(shared) {
            s.handle.shutdown(); // stop router + batch workers
        }
        summary
    }
}

/// Best-effort `503` for connections arriving past the pending queue.
/// Written as HTTP so curl/probes see a structured answer; binary
/// clients observe the close and surface a truncation error.
fn reject_overloaded(mut s: TcpStream) {
    let err = ServeError::Overloaded;
    let body = format!(
        "{{\"error\":\"{}\",\"code\":{},\"message\":\"{}\"}}\n",
        err.name(),
        err.code(),
        err.message()
    );
    let _ = s.set_write_timeout(Some(Duration::from_millis(250)));
    let _ = write!(
        s,
        "HTTP/1.1 503 Service Unavailable\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
}

/// Client-side retry policy for transient transport failures: capped
/// exponential backoff with deterministic jitter.  The default (`max:
/// 0`) disables retries entirely — existing callers see byte-identical
/// behavior unless they opt in (CLI: `--retry-max` / `--retry-base-ms`).
///
/// What retries and what doesn't is the load-bearing part:
///
/// * **Retried**: connection refusal and transport-level failures
///   (`connect: `/`send: `/`read: ` IO errors, a server that closed the
///   connection mid-stream) — the failure modes of a restarting or
///   momentarily unreachable server — plus [`ServeError::Overloaded`],
///   which is the server explicitly asking for later, spread by backoff.
/// * **Never retried**: `BadRequest`/`DimMismatch` (resending a bad
///   request yields the same answer), `Timeout` (the budget is spent;
///   the caller owns deciding whether more waiting is acceptable) and
///   `ShuttingDown` (the server told us not to come back).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Retry attempts after the initial try; 0 = retries off.
    pub max: u32,
    /// First backoff delay in milliseconds; doubles per attempt, capped
    /// at 5 s.
    pub base_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { max: 0, base_ms: 50 }
    }
}

impl RetryPolicy {
    /// Backoff before retry `attempt` (0-based): `base · 2^attempt`,
    /// capped, plus up to 25% deterministic jitter (hash of the attempt
    /// and a caller salt — no entropy source, so test runs replay).
    /// Public because the cluster's shard-heal backoff reuses it.
    pub fn delay(&self, attempt: u32, salt: u64) -> Duration {
        const CAP_MS: u64 = 5_000;
        let exp = self.base_ms.max(1).saturating_mul(1u64 << attempt.min(12)).min(CAP_MS);
        let jitter = crate::util::hash::mix64(salt ^ u64::from(attempt)) % (exp / 4).max(1);
        Duration::from_millis(exp + jitter)
    }
}

/// A small blocking client for the binary protocol — what the protocol
/// tests, the CI smoke leg and operator tooling speak.
pub struct WireClient {
    reader: std::io::BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
    /// Where we connected, for transparent reconnects mid-retry.  `None`
    /// when the peer address could not be observed — retries then fail
    /// over to surfacing the original error.
    peer: Option<SocketAddr>,
    policy: RetryPolicy,
}

impl WireClient {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Self, ServeError> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| ServeError::internal(format!("connect: {e}")))?;
        let _ = stream.set_nodelay(true);
        let peer = stream.peer_addr().ok();
        let writer = stream
            .try_clone()
            .map_err(|e| ServeError::internal(format!("clone stream: {e}")))?;
        Ok(Self {
            reader: std::io::BufReader::new(stream),
            writer,
            next_id: 1,
            peer,
            policy: RetryPolicy::default(),
        })
    }

    /// Connect with retries on refusal (a server that is still binding,
    /// or restarting) and install `policy` for subsequent requests.
    pub fn connect_with_retry<A: ToSocketAddrs>(
        addr: A,
        policy: RetryPolicy,
    ) -> Result<Self, ServeError> {
        let mut attempt = 0u32;
        loop {
            match Self::connect(&addr) {
                Ok(mut client) => {
                    client.policy = policy;
                    return Ok(client);
                }
                Err(e) if attempt < policy.max && Self::transient(&e) => {
                    std::thread::sleep(policy.delay(attempt, 0x5EED));
                    attempt += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Install a retry policy on an existing client.
    pub fn set_retry(&mut self, policy: RetryPolicy) {
        self.policy = policy;
    }

    /// Whether `e` is worth retrying at all (see [`RetryPolicy`]).
    fn transient(e: &ServeError) -> bool {
        match e {
            ServeError::Overloaded => true,
            ServeError::Internal(msg) => {
                msg.starts_with("connect: ")
                    || msg.starts_with("send: ")
                    || msg.starts_with("read: ")
                    || msg == "server closed the connection"
            }
            _ => false,
        }
    }

    /// Transport failures invalidate the stream (a half-written frame
    /// would desynchronize the protocol); `Overloaded` arrives as a
    /// well-formed error frame on a healthy connection.
    fn needs_reconnect(e: &ServeError) -> bool {
        !matches!(e, ServeError::Overloaded)
    }

    /// Replace the underlying stream with a fresh connection to the
    /// original peer.  Request ids stay monotonic across reconnects so
    /// late replies from the old stream can never match a new id.
    fn reconnect(&mut self) -> Result<(), ServeError> {
        let peer = self
            .peer
            .ok_or_else(|| ServeError::internal("connect: peer address unknown"))?;
        let fresh = Self::connect(peer)?;
        self.reader = fresh.reader;
        self.writer = fresh.writer;
        Ok(())
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Send one frame (any kind — tests use this to send malformed
    /// sequences too).
    pub fn send(&mut self, frame: &Frame) -> Result<(), ServeError> {
        proto::write_frame(&mut self.writer, frame)
            .map_err(|e| ServeError::internal(format!("send: {e}")))
    }

    /// Receive the next frame (blocking; the socket has no read
    /// timeout, so `Idle` cannot occur).
    pub fn recv(&mut self) -> Result<Frame, ServeError> {
        loop {
            if crate::util::fault::should_fire("io.read") {
                continue; // simulated EAGAIN, client side: skip one read
            }
            let out = proto::read_frame(
                &mut self.reader,
                proto::MAX_FRAME_PAYLOAD,
                Duration::from_secs(60),
            )?;
            match out {
                ReadOutcome::Frame(f) => return Ok(f),
                ReadOutcome::Idle => continue,
                ReadOutcome::Eof => {
                    return Err(ServeError::internal("server closed the connection"));
                }
            }
        }
    }

    /// Submit a classify request without waiting for the reply
    /// (pipelining); returns the request id.
    pub fn send_classify(&mut self, method: &Method, input: &[f32]) -> Result<u64, ServeError> {
        self.send_classify_with_deadline(method, input, None)
    }

    /// Like [`send_classify`](Self::send_classify) but carrying an
    /// explicit latency budget; `Some` stamps the frame as protocol v2.
    pub fn send_classify_with_deadline(
        &mut self,
        method: &Method,
        input: &[f32],
        deadline_ms: Option<u64>,
    ) -> Result<u64, ServeError> {
        let id = self.fresh_id();
        self.send(&Frame::Request {
            id,
            method: method.clone(),
            input: input.to_vec(),
            deadline_ms,
        })?;
        Ok(id)
    }

    /// One classify round-trip; an error frame becomes `Err`.
    pub fn classify(&mut self, method: &Method, input: &[f32]) -> Result<WireResponse, ServeError> {
        self.classify_with_deadline(method, input, None)
    }

    /// One classify round-trip with an explicit latency budget.  Under a
    /// non-default [`RetryPolicy`] transient failures are retried with
    /// backoff (reconnecting when the transport broke); request errors
    /// surface immediately — see [`RetryPolicy`] for the split.
    pub fn classify_with_deadline(
        &mut self,
        method: &Method,
        input: &[f32],
        deadline_ms: Option<u64>,
    ) -> Result<WireResponse, ServeError> {
        let mut attempt = 0u32;
        loop {
            match self.classify_once(method, input, deadline_ms) {
                Ok(resp) => return Ok(resp),
                Err(e) if attempt < self.policy.max && Self::transient(&e) => {
                    let policy = self.policy;
                    std::thread::sleep(policy.delay(attempt, self.next_id));
                    attempt += 1;
                    if Self::needs_reconnect(&e) && self.reconnect().is_err() {
                        return Err(e);
                    }
                }
                Err(e) => return Err(e),
            }
        }
    }

    fn classify_once(
        &mut self,
        method: &Method,
        input: &[f32],
        deadline_ms: Option<u64>,
    ) -> Result<WireResponse, ServeError> {
        let id = self.send_classify_with_deadline(method, input, deadline_ms)?;
        match self.recv()? {
            Frame::Response { id: rid, resp } if rid == id => Ok(resp),
            Frame::Error { err, .. } => Err(err),
            other => Err(ServeError::internal(format!(
                "unexpected reply frame (id {})",
                other.id()
            ))),
        }
    }

    pub fn ping(&mut self) -> Result<(), ServeError> {
        let id = self.fresh_id();
        self.send(&Frame::Ping { id })?;
        match self.recv()? {
            Frame::Pong { id: rid } if rid == id => Ok(()),
            other => Err(ServeError::internal(format!(
                "unexpected ping reply (id {})",
                other.id()
            ))),
        }
    }

    /// Fetch the server's metrics JSON over the binary protocol.
    pub fn metrics_text(&mut self) -> Result<String, ServeError> {
        let id = self.fresh_id();
        self.send(&Frame::MetricsRequest { id })?;
        match self.recv()? {
            Frame::MetricsText { id: rid, text } if rid == id => Ok(text),
            Frame::Error { err, .. } => Err(err),
            other => Err(ServeError::internal(format!(
                "unexpected metrics reply (id {})",
                other.id()
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_fills_defaults_and_validates() {
        let cfg = ServeConfig::builder().build().expect("default config");
        assert!(cfg.engine.workers >= 1);
        assert!(cfg.engine.shards >= 1);
        assert_eq!(cfg.server.workers, 1, "one dispatch worker by default");
        assert!(cfg.server.deadline.is_none(), "no default deadline");
        assert!(cfg.net.listen.is_none());

        for (b, what) in [
            (ServeConfig::builder().alpha(0.0), "alpha 0"),
            (ServeConfig::builder().alpha(1.5), "alpha > 1"),
            (ServeConfig::builder().shards(0), "zero shards"),
            (ServeConfig::builder().workers(0), "zero workers"),
            (ServeConfig::builder().max_batch(0), "zero max_batch"),
            (ServeConfig::builder().cache_mb(0).snapshot("x.bin"), "snapshot sans cache"),
        ] {
            let err = b.build().unwrap_err();
            assert!(matches!(err, ServeError::BadRequest(_)), "{what}: {err:?}");
        }
    }

    #[test]
    fn builder_overrides_beat_env_and_defaults() {
        let cfg = ServeConfig::builder()
            .workers(3)
            .seed(42)
            .cache_mb(4)
            .shards(2)
            .memo_mb(2)
            .max_batch(1)
            .deadline_ms(250)
            .listen("127.0.0.1:0")
            .conn_threads(2)
            .build()
            .expect("explicit config");
        assert_eq!(cfg.engine.workers, 3);
        assert_eq!(cfg.engine.seed, 42);
        assert!(cfg.engine.cache.enabled());
        assert_eq!(cfg.engine.shards, 2);
        assert!(cfg.engine.memo.enabled());
        assert_eq!(cfg.server.max_batch, 1);
        assert_eq!(cfg.server.deadline, Some(Duration::from_millis(250)));
        assert_eq!(cfg.net.listen.as_deref(), Some("127.0.0.1:0"));
        assert_eq!(cfg.net.conn_threads, 2);
        // explicit 0 must mean "off", not "fall back to env"
        let off = ServeConfig::builder().cache_mb(0).memo_mb(0).deadline_ms(0).build().unwrap();
        assert!(!off.engine.cache.enabled());
        assert!(!off.engine.memo.enabled());
        assert!(off.server.deadline.is_none(), "deadline 0 means off");
    }

    #[test]
    fn deployment_selects_the_backend_shape() {
        let model = || BnnModel::synthetic(&[16, 12, 8, 5], 11);
        let single = ServeConfig::builder().shards(1).memo_mb(0).cache_mb(0).build().unwrap();
        let d = Deployment::new(model(), &single);
        assert_eq!(d.shards(), 1);
        assert_eq!(d.input_dim(), 16);
        assert_eq!(d.output_dim(), 5);
        assert!(d.save_snapshot().is_none(), "no snapshot configured");

        let sharded = ServeConfig::builder().shards(2).memo_mb(0).cache_mb(0).build().unwrap();
        let d = Deployment::new(model(), &sharded);
        assert_eq!(d.shards(), 2);
        // a memo-enabled config is a cluster even at one shard
        let memoed = ServeConfig::builder().shards(1).memo_mb(2).cache_mb(0).build().unwrap();
        let d = Deployment::new(model(), &memoed);
        let mut s = crate::coordinator::metrics::Metrics::new().summary();
        d.fold_metrics(&mut s);
        assert!(s.memo.is_some(), "cluster summary carries memo counters");
    }

    #[test]
    fn retry_policy_classifies_errors_and_caps_backoff() {
        // retried: capacity + transport
        assert!(WireClient::transient(&ServeError::Overloaded));
        assert!(WireClient::transient(&ServeError::internal("connect: refused")));
        assert!(WireClient::transient(&ServeError::internal("send: broken pipe")));
        assert!(WireClient::transient(&ServeError::internal("read: reset")));
        assert!(WireClient::transient(&ServeError::internal("server closed the connection")));
        // never retried: request errors, spent budgets, lifecycle
        assert!(!WireClient::transient(&ServeError::BadRequest("x".into())));
        assert!(!WireClient::transient(&ServeError::DimMismatch("x".into())));
        assert!(!WireClient::transient(&ServeError::Timeout));
        assert!(!WireClient::transient(&ServeError::ShuttingDown));
        assert!(!WireClient::transient(&ServeError::internal("backend exploded")));

        let p = RetryPolicy { max: 5, base_ms: 50 };
        for attempt in 0..40 {
            let d = p.delay(attempt, 7);
            assert!(d >= Duration::from_millis(50), "attempt {attempt}: {d:?}");
            // 5s cap + 25% jitter headroom
            assert!(d <= Duration::from_millis(6_250), "attempt {attempt}: {d:?}");
        }
        assert_eq!(p.delay(3, 9), p.delay(3, 9), "backoff must be deterministic");
        assert_eq!(RetryPolicy::default().max, 0, "retries are strictly opt-in");
    }

    #[test]
    fn connect_with_retry_gives_up_with_the_connect_error() {
        // a port with no listener: refusal is transient, so the budget is
        // spent, then the underlying error surfaces
        let e = WireClient::connect_with_retry(
            "127.0.0.1:1",
            RetryPolicy { max: 2, base_ms: 1 },
        )
        .unwrap_err();
        assert!(e.to_string().starts_with("connect: "), "{e}");
    }
}
