//! Canonical serving errors with stable wire codes.
//!
//! Every fallible step on the request path — validation, dispatch, the
//! batcher, the cluster router, snapshot persistence and the network
//! front door — reports a [`ServeError`].  The enum is the single error
//! vocabulary shared by the in-process API (`Pending::wait`,
//! `ClusterRouter::evaluate`) and the wire protocol (`serve::proto`
//! `Error` frames, HTTP statuses), replacing the former
//! `Result<_, String>` plumbing.
//!
//! Wire codes are **stable**: they are part of the binary protocol and
//! must never be renumbered (new variants append new codes).
//!
//! | code | variant        | HTTP | meaning                                   |
//! |------|----------------|------|-------------------------------------------|
//! | 1    | `BadRequest`   | 400  | malformed frame / body / method           |
//! | 2    | `DimMismatch`  | 400  | input length ≠ model input dimension      |
//! | 3    | `Overloaded`   | 503  | connection/queue capacity exhausted       |
//! | 4    | `Timeout`      | 504  | request or I/O deadline exceeded          |
//! | 5    | `ShuttingDown` | 503  | server is draining, request not admitted  |
//! | 6    | `Internal`     | 500  | backend failure (message carries detail)  |

use std::fmt;

/// A serving-path error with a stable wire code.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Malformed request: bad frame, bad JSON, unusable method.
    BadRequest(String),
    /// Input vector length does not match the model's input dimension.
    DimMismatch(String),
    /// Capacity exhausted: the server cannot admit the connection/request.
    Overloaded,
    /// A read/write or end-to-end request deadline expired.
    Timeout,
    /// The server is draining and no longer admits new work.
    ShuttingDown,
    /// Backend-side failure; the message is diagnostic, not protocol.
    Internal(String),
}

impl ServeError {
    /// Build an `Internal` error from anything printable.
    pub fn internal<M: fmt::Display>(msg: M) -> Self {
        ServeError::Internal(msg.to_string())
    }

    /// Build a `BadRequest` error from anything printable.
    pub fn bad_request<M: fmt::Display>(msg: M) -> Self {
        ServeError::BadRequest(msg.to_string())
    }

    /// The stable wire code carried by binary `Error` frames.
    pub const fn code(&self) -> u16 {
        match self {
            ServeError::BadRequest(_) => 1,
            ServeError::DimMismatch(_) => 2,
            ServeError::Overloaded => 3,
            ServeError::Timeout => 4,
            ServeError::ShuttingDown => 5,
            ServeError::Internal(_) => 6,
        }
    }

    /// Reconstruct from a wire code + detail message (the decode side of
    /// [`ServeError::code`]).  Unknown codes map to `Internal` so old
    /// clients survive new server variants.
    pub fn from_wire(code: u16, msg: String) -> Self {
        match code {
            1 => ServeError::BadRequest(msg),
            2 => ServeError::DimMismatch(msg),
            3 => ServeError::Overloaded,
            4 => ServeError::Timeout,
            5 => ServeError::ShuttingDown,
            6 => ServeError::Internal(msg),
            other => ServeError::Internal(format!("unknown error code {other}: {msg}")),
        }
    }

    /// The HTTP status line equivalent for the HTTP/1.1 shim.
    pub const fn http_status(&self) -> (u16, &'static str) {
        match self {
            ServeError::BadRequest(_) | ServeError::DimMismatch(_) => (400, "Bad Request"),
            ServeError::Overloaded | ServeError::ShuttingDown => (503, "Service Unavailable"),
            ServeError::Timeout => (504, "Gateway Timeout"),
            ServeError::Internal(_) => (500, "Internal Server Error"),
        }
    }

    /// Stable short name (used in HTTP error bodies and logs).
    pub const fn name(&self) -> &'static str {
        match self {
            ServeError::BadRequest(_) => "bad_request",
            ServeError::DimMismatch(_) => "dim_mismatch",
            ServeError::Overloaded => "overloaded",
            ServeError::Timeout => "timeout",
            ServeError::ShuttingDown => "shutting_down",
            ServeError::Internal(_) => "internal",
        }
    }

    /// The detail message (empty for the unit variants).
    pub fn message(&self) -> &str {
        match self {
            ServeError::BadRequest(m) | ServeError::DimMismatch(m) | ServeError::Internal(m) => m,
            ServeError::Overloaded => "server overloaded",
            ServeError::Timeout => "request timed out",
            ServeError::ShuttingDown => "server shutting down",
        }
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Message-forward: pre-redesign callers matched on substrings of
        // the old `String` errors ("dim", "zero voters", "backend
        // unavailable", ...), so Display stays the bare detail message.
        f.write_str(self.message())
    }
}

impl std::error::Error for ServeError {}

/// Shim: legacy callers that still want a `String` error.
impl From<ServeError> for String {
    fn from(e: ServeError) -> String {
        e.to_string()
    }
}

/// Shim: legacy `Result<_, String>` producers entering the new API.
impl From<String> for ServeError {
    fn from(s: String) -> ServeError {
        ServeError::Internal(s)
    }
}

impl From<&str> for ServeError {
    fn from(s: &str) -> ServeError {
        ServeError::Internal(s.to_string())
    }
}

/// Shim into the crate-wide string-backed [`crate::util::error::Error`].
impl From<ServeError> for crate::util::error::Error {
    fn from(e: ServeError) -> crate::util::error::Error {
        crate::util::error::Error::msg(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all() -> Vec<ServeError> {
        vec![
            ServeError::BadRequest("bad frame".into()),
            ServeError::DimMismatch("input 0: dim 3 != model dim 784".into()),
            ServeError::Overloaded,
            ServeError::Timeout,
            ServeError::ShuttingDown,
            ServeError::Internal("worker died".into()),
        ]
    }

    #[test]
    fn codes_are_stable_and_distinct() {
        let codes: Vec<u16> = all().iter().map(|e| e.code()).collect();
        assert_eq!(codes, vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn wire_round_trip() {
        for e in all() {
            let back = ServeError::from_wire(e.code(), e.message().to_string());
            assert_eq!(back.code(), e.code());
            // Message-carrying variants round-trip exactly.
            match &e {
                ServeError::BadRequest(_)
                | ServeError::DimMismatch(_)
                | ServeError::Internal(_) => {
                    assert_eq!(back, e)
                }
                _ => {}
            }
        }
        // Unknown code degrades to Internal, not a panic.
        let u = ServeError::from_wire(999, "later variant".into());
        assert_eq!(u.code(), 6);
    }

    #[test]
    fn string_shims() {
        let e = ServeError::DimMismatch("input 0: dim 3 != model dim 16".into());
        let s: String = e.clone().into();
        assert!(s.contains("dim"));
        let back: ServeError = s.into();
        assert_eq!(back.code(), 6); // legacy strings arrive as Internal
        assert_eq!(ServeError::from("oops").code(), 6);
    }

    #[test]
    fn http_statuses() {
        assert_eq!(ServeError::Overloaded.http_status().0, 503);
        assert_eq!(ServeError::Timeout.http_status().0, 504);
        assert_eq!(ServeError::bad_request("x").http_status().0, 400);
        assert_eq!(ServeError::internal("x").http_status().0, 500);
    }
}
