//! The length-prefixed binary wire protocol (versioned frames).
//!
//! Every message is one frame: a fixed 20-byte little-endian header
//! followed by a kind-specific payload.
//!
//! ```text
//! offset  size  field
//! 0       4     magic   "BDM1"
//! 4       1     version (1, or 2 for deadline-carrying requests)
//! 5       1     kind    (see below)
//! 6       2     reserved (0 on encode, ignored on decode)
//! 8       8     request id (echoed verbatim in the reply)
//! 16      4     payload length in bytes
//! ```
//!
//! Versioning is **per frame**: a Request carrying a completion deadline
//! appends a trailing `u64` deadline (milliseconds) to its payload and
//! stamps version 2; every other frame — including deadline-less
//! requests — still encodes version 1, so an old server only rejects the
//! frames it genuinely cannot honor and an old client never sees a
//! version it does not speak.
//!
//! Version 3 appends a CRC-32 (IEEE) of the payload as the final four
//! payload bytes, so payload corruption — not just a smashed magic —
//! is detectable.  Decoding always accepts v3; *emitting* v3 is opt-in
//! ([`set_crc_frames`] / `BAYESDM_PROTO_CRC=1`) so default traffic
//! stays byte-identical to v1/v2 peers.  After the checksum is
//! verified and stripped, a v3 payload parses exactly like v2 (the
//! optional trailing deadline included).
//!
//! Frame kinds: 1 = Request, 2 = Response, 3 = Error, 4 = Ping,
//! 5 = Pong, 6 = MetricsRequest, 7 = MetricsText.  Responses carry the
//! raw f32 **bits** of confidence/entropy, so a wire client observes the
//! exact values the in-process path computes (the bit-parity contract
//! `tests/serve_proto.rs` pins).
//!
//! Request payloads encode the inference method (tag 0 = Standard,
//! 1 = Hybrid, 2 = DM-BNN with an explicit per-layer schedule) and the
//! input vector as raw f32 bits.  Error payloads carry the stable
//! [`ServeError`] wire code plus a UTF-8 detail message.
//!
//! Decoding is defensive: bad magic, unknown version/kind, truncated or
//! trailing payload bytes and oversized frames all surface as
//! [`ServeError::BadRequest`]; a mid-frame stall longer than the I/O
//! deadline surfaces as [`ServeError::Timeout`].

use std::io::{ErrorKind, Read, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Once;
use std::time::{Duration, Instant};

use crate::util::hash::crc32;

use crate::nn::bnn::Method;

use super::error::ServeError;

/// Frame magic — also the protocol-sniffing prefix (no HTTP method
/// starts with `B`, so one peeked byte routes a connection).
pub const MAGIC: [u8; 4] = *b"BDM1";
/// Base wire protocol version.
pub const PROTO_VERSION: u8 = 1;
/// Version stamped on Request frames that carry a trailing `u64`
/// deadline (ms).  Only emitted when a deadline is present, so
/// deadline-less traffic stays byte-identical to version-1 clients.
pub const PROTO_VERSION_DEADLINE: u8 = 2;
/// Version whose payloads end in a CRC-32 (IEEE) of the preceding
/// payload bytes.  Always accepted on decode; emitted only when CRC
/// frames are enabled ([`set_crc_frames`] / `BAYESDM_PROTO_CRC`).
pub const PROTO_VERSION_CRC: u8 = 3;
/// Fixed header size in bytes.
pub const HEADER_BYTES: usize = 20;
/// Default cap on a single frame's payload (16 MiB) — far above any
/// legitimate request, small enough to bound a hostile length prefix.
pub const MAX_FRAME_PAYLOAD: usize = 16 << 20;

const KIND_REQUEST: u8 = 1;
const KIND_RESPONSE: u8 = 2;
const KIND_ERROR: u8 = 3;
const KIND_PING: u8 = 4;
const KIND_PONG: u8 = 5;
const KIND_METRICS_REQ: u8 = 6;
const KIND_METRICS_TEXT: u8 = 7;

const METHOD_STANDARD: u8 = 0;
const METHOD_HYBRID: u8 = 1;
const METHOD_DM: u8 = 2;

/// Sanity bound on a DM schedule's length in a request frame.
const MAX_SCHEDULE_LEN: usize = 1024;

/// A served answer on the wire.  `confidence`/`entropy` round-trip by
/// bits; `latency_us` is the server-side queue+compute latency.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WireResponse {
    pub class: u32,
    pub voters: u32,
    pub confidence: f32,
    pub entropy: f32,
    pub latency_us: u64,
}

/// One decoded protocol frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Classify `input` with `method`; the reply echoes `id`.
    /// `deadline_ms` is the client's completion budget, measured from
    /// server receipt — `Some` upgrades the frame to version 2 on the
    /// wire (trailing `u64`).
    Request { id: u64, method: Method, input: Vec<f32>, deadline_ms: Option<u64> },
    Response { id: u64, resp: WireResponse },
    Error { id: u64, err: ServeError },
    Ping { id: u64 },
    Pong { id: u64 },
    MetricsRequest { id: u64 },
    /// Rendered `MetricsSummary` JSON (server → client).
    MetricsText { id: u64, text: String },
}

impl Frame {
    pub fn id(&self) -> u64 {
        match self {
            Frame::Request { id, .. }
            | Frame::Response { id, .. }
            | Frame::Error { id, .. }
            | Frame::Ping { id }
            | Frame::Pong { id }
            | Frame::MetricsRequest { id }
            | Frame::MetricsText { id, .. } => *id,
        }
    }

    /// Wire kind code (1 = Request … 7 = MetricsText); stable, also
    /// used as the frame-kind word in flight-recorder events.
    pub fn kind(&self) -> u8 {
        match self {
            Frame::Request { .. } => KIND_REQUEST,
            Frame::Response { .. } => KIND_RESPONSE,
            Frame::Error { .. } => KIND_ERROR,
            Frame::Ping { .. } => KIND_PING,
            Frame::Pong { .. } => KIND_PONG,
            Frame::MetricsRequest { .. } => KIND_METRICS_REQ,
            Frame::MetricsText { .. } => KIND_METRICS_TEXT,
        }
    }

    /// The header version this frame encodes with (per-frame gating: see
    /// the module docs).
    fn version(&self) -> u8 {
        match self {
            Frame::Request { deadline_ms: Some(_), .. } => PROTO_VERSION_DEADLINE,
            _ => PROTO_VERSION,
        }
    }
}

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    for &x in xs {
        push_u32(buf, x.to_bits());
    }
}

fn encode_payload(frame: &Frame) -> Vec<u8> {
    let mut p = Vec::new();
    match frame {
        Frame::Request { method, input, deadline_ms, .. } => {
            match method {
                Method::Standard { t } => {
                    p.push(METHOD_STANDARD);
                    push_u32(&mut p, *t as u32);
                }
                Method::Hybrid { t } => {
                    p.push(METHOD_HYBRID);
                    push_u32(&mut p, *t as u32);
                }
                Method::DmBnn { schedule } => {
                    p.push(METHOD_DM);
                    push_u32(&mut p, schedule.len() as u32);
                    for &s in schedule {
                        push_u32(&mut p, s as u32);
                    }
                }
            }
            push_u32(&mut p, input.len() as u32);
            push_f32s(&mut p, input);
            if let Some(d) = deadline_ms {
                p.extend_from_slice(&d.to_le_bytes());
            }
        }
        Frame::Response { resp, .. } => {
            push_u32(&mut p, resp.class);
            push_u32(&mut p, resp.voters);
            push_u32(&mut p, resp.confidence.to_bits());
            push_u32(&mut p, resp.entropy.to_bits());
            p.extend_from_slice(&resp.latency_us.to_le_bytes());
        }
        Frame::Error { err, .. } => {
            p.extend_from_slice(&err.code().to_le_bytes());
            p.extend_from_slice(err.message().as_bytes());
        }
        Frame::MetricsText { text, .. } => p.extend_from_slice(text.as_bytes()),
        Frame::Ping { .. } | Frame::Pong { .. } | Frame::MetricsRequest { .. } => {}
    }
    p
}

static CRC_ENV: Once = Once::new();
static CRC_FRAMES: AtomicBool = AtomicBool::new(false);

/// Whether this process emits v3 CRC frames.  Resolved once from
/// `BAYESDM_PROTO_CRC` on first use; defaults off so the wire stays
/// byte-identical to v1/v2 peers.
pub fn crc_frames() -> bool {
    CRC_ENV.call_once(|| {
        let on = std::env::var("BAYESDM_PROTO_CRC")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false);
        CRC_FRAMES.store(on, Ordering::Relaxed);
    });
    CRC_FRAMES.load(Ordering::Relaxed)
}

/// Force CRC-frame emission on or off (overrides the environment).
pub fn set_crc_frames(on: bool) {
    CRC_ENV.call_once(|| {}); // pin env resolution so it cannot undo this
    CRC_FRAMES.store(on, Ordering::Relaxed);
}

/// Encode one frame (header + payload) into a fresh buffer, emitting
/// v3 when CRC frames are enabled process-wide.
pub fn encode(frame: &Frame) -> Vec<u8> {
    encode_with(frame, crc_frames())
}

/// Encode with an explicit CRC choice (the test seam; `encode` applies
/// the process-wide setting).
pub fn encode_with(frame: &Frame, crc: bool) -> Vec<u8> {
    let mut payload = encode_payload(frame);
    let version = if crc {
        let sum = crc32(&payload);
        payload.extend_from_slice(&sum.to_le_bytes());
        PROTO_VERSION_CRC
    } else {
        frame.version()
    };
    let mut buf = Vec::with_capacity(HEADER_BYTES + payload.len());
    buf.extend_from_slice(&MAGIC);
    buf.push(version);
    buf.push(frame.kind());
    buf.extend_from_slice(&0u16.to_le_bytes());
    buf.extend_from_slice(&frame.id().to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&payload);
    buf
}

/// Write one frame to `w` (single buffered write + flush).
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> std::io::Result<()> {
    let mut buf = encode(frame);
    if crate::util::fault::should_fire("frame.corrupt") {
        // flip the first payload byte: detectable by the v3 CRC, and
        // exactly the corruption v1/v2 frames cannot see.  Frames with
        // no payload fall back to smashing the magic, which every
        // version rejects.
        let i = if buf.len() > HEADER_BYTES { HEADER_BYTES } else { 0 };
        buf[i] ^= 0xFF;
    }
    w.write_all(&buf)?;
    w.flush()
}

/// Bounds-checked little-endian payload reader.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], ServeError> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| ServeError::bad_request("truncated frame payload"))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, ServeError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, ServeError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, ServeError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, ServeError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, ServeError> {
        let bytes = n
            .checked_mul(4)
            .ok_or_else(|| ServeError::bad_request("frame length overflow"))?;
        let raw = self.take(bytes)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }

    fn finish(&self) -> Result<(), ServeError> {
        if self.pos != self.buf.len() {
            return Err(ServeError::bad_request(format!(
                "{} trailing bytes after frame payload",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

/// Decode a frame payload given its header fields.  Exposed for the
/// protocol test suite; `read_frame` is the streaming entry point.
pub fn decode_payload(
    kind: u8,
    id: u64,
    payload: &[u8],
    version: u8,
) -> Result<Frame, ServeError> {
    // v3: the last four payload bytes are a CRC-32 of everything before
    // them; verify, strip, then parse like v2.
    let payload = if version >= PROTO_VERSION_CRC {
        let Some(split) = payload.len().checked_sub(4) else {
            return Err(ServeError::bad_request("v3 frame too short for its checksum"));
        };
        let (body, tail) = payload.split_at(split);
        let want = u32::from_le_bytes(tail.try_into().unwrap());
        if crc32(body) != want {
            return Err(ServeError::bad_request("frame payload checksum mismatch"));
        }
        body
    } else {
        payload
    };
    let mut r = Reader { buf: payload, pos: 0 };
    let frame = match kind {
        KIND_REQUEST => {
            let method = match r.u8()? {
                METHOD_STANDARD => Method::Standard { t: r.u32()? as usize },
                METHOD_HYBRID => Method::Hybrid { t: r.u32()? as usize },
                METHOD_DM => {
                    let len = r.u32()? as usize;
                    if len > MAX_SCHEDULE_LEN {
                        return Err(ServeError::bad_request(format!(
                            "schedule length {len} exceeds {MAX_SCHEDULE_LEN}"
                        )));
                    }
                    let mut schedule = Vec::with_capacity(len);
                    for _ in 0..len {
                        schedule.push(r.u32()? as usize);
                    }
                    Method::DmBnn { schedule }
                }
                tag => return Err(ServeError::bad_request(format!("unknown method tag {tag}"))),
            };
            let n = r.u32()? as usize;
            let input = r.f32s(n)?;
            // Version ≥ 2 may append a u64 deadline; a v2 request
            // without one (trailing bytes absent) is still well-formed.
            let deadline_ms = if version >= PROTO_VERSION_DEADLINE && r.pos < payload.len() {
                Some(r.u64()?)
            } else {
                None
            };
            Frame::Request { id, method, input, deadline_ms }
        }
        KIND_RESPONSE => Frame::Response {
            id,
            resp: WireResponse {
                class: r.u32()?,
                voters: r.u32()?,
                confidence: f32::from_bits(r.u32()?),
                entropy: f32::from_bits(r.u32()?),
                latency_us: r.u64()?,
            },
        },
        KIND_ERROR => {
            let code = r.u16()?;
            let msg = String::from_utf8_lossy(r.take(payload.len() - 2)?).into_owned();
            Frame::Error { id, err: ServeError::from_wire(code, msg) }
        }
        KIND_PING => Frame::Ping { id },
        KIND_PONG => Frame::Pong { id },
        KIND_METRICS_REQ => Frame::MetricsRequest { id },
        KIND_METRICS_TEXT => Frame::MetricsText {
            id,
            text: String::from_utf8_lossy(payload).into_owned(),
        },
        k => return Err(ServeError::bad_request(format!("unknown frame kind {k}"))),
    };
    r.finish()?;
    Ok(frame)
}

/// Outcome of one streaming read attempt.
#[derive(Debug)]
pub enum ReadOutcome {
    /// A complete frame arrived.
    Frame(Frame),
    /// Clean EOF at a frame boundary (peer closed the connection).
    Eof,
    /// The socket's poll tick expired with **zero** bytes of a new frame
    /// read — the connection is idle, not timed out.  Callers loop on
    /// this (checking their drain flag) to stay responsive.
    Idle,
}

/// Fill `buf` from `r`, tolerating short reads.  `started` reports
/// whether any byte of this frame had already arrived: a read timeout
/// before the first byte is [`ReadOutcome::Idle`] territory (`Ok(false)`
/// return), after it the frame is mid-flight and the `deadline` applies.
fn read_full<R: Read>(
    r: &mut R,
    buf: &mut [u8],
    mut got: usize,
    deadline: &mut Option<Instant>,
    io_timeout: Duration,
) -> Result<Option<usize>, ServeError> {
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 && deadline.is_none() {
                    return Ok(None); // clean EOF at a frame boundary
                }
                return Err(ServeError::bad_request("truncated frame: peer closed mid-frame"));
            }
            Ok(n) => {
                got += n;
                if deadline.is_none() {
                    *deadline = Some(Instant::now() + io_timeout);
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                match *deadline {
                    // no byte of this frame yet: idle tick, not an error
                    None => return Ok(Some(got)),
                    Some(d) if Instant::now() >= d => return Err(ServeError::Timeout),
                    Some(_) => {}
                }
            }
            Err(e) => return Err(ServeError::internal(format!("read: {e}"))),
        }
    }
    Ok(Some(got))
}

/// Read one frame from `r`.
///
/// `r`'s read timeout should be a short poll tick (see
/// `conn::POLL_TICK`); `io_timeout` is the end-to-end deadline for a
/// frame once its first byte has arrived.  Returns [`ReadOutcome::Idle`]
/// when the tick expires before any byte of a new frame, so callers can
/// check shutdown flags between frames without dropping data.
pub fn read_frame<R: Read>(
    r: &mut R,
    max_payload: usize,
    io_timeout: Duration,
) -> Result<ReadOutcome, ServeError> {
    let mut hdr = [0u8; HEADER_BYTES];
    let mut deadline: Option<Instant> = None;
    let mut got = 0usize;
    loop {
        match read_full(r, &mut hdr, got, &mut deadline, io_timeout)? {
            None => return Ok(ReadOutcome::Eof),
            Some(n) if n < HEADER_BYTES => {
                if n == 0 {
                    return Ok(ReadOutcome::Idle);
                }
                got = n; // partial header: keep collecting under the deadline
            }
            Some(_) => break,
        }
    }

    if hdr[0..4] != MAGIC {
        return Err(ServeError::bad_request("bad frame magic"));
    }
    let version = hdr[4];
    if !(PROTO_VERSION..=PROTO_VERSION_CRC).contains(&version) {
        return Err(ServeError::bad_request(format!(
            "unsupported protocol version {version} \
             (expected {PROTO_VERSION}..={PROTO_VERSION_CRC})"
        )));
    }
    let kind = hdr[5];
    let id = u64::from_le_bytes(hdr[8..16].try_into().unwrap());
    let len = u32::from_le_bytes(hdr[16..20].try_into().unwrap()) as usize;
    if len > max_payload {
        return Err(ServeError::bad_request(format!(
            "oversized frame: {len} bytes exceeds the {max_payload}-byte cap"
        )));
    }

    let mut payload = vec![0u8; len];
    let mut got = 0usize;
    while got < len {
        match read_full(r, &mut payload, got, &mut deadline, io_timeout)? {
            None => unreachable!("EOF handled as truncation once the header arrived"),
            Some(n) if n < len => got = n,
            Some(_) => break,
        }
    }
    Ok(ReadOutcome::Frame(decode_payload(kind, id, &payload, version)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    const T: Duration = Duration::from_secs(1);

    fn round_trip(f: &Frame) -> Frame {
        let bytes = encode(f);
        let mut c = Cursor::new(bytes);
        match read_frame(&mut c, MAX_FRAME_PAYLOAD, T).expect("decode") {
            ReadOutcome::Frame(g) => g,
            other => panic!("expected a frame, got {other:?}"),
        }
    }

    #[test]
    fn all_kinds_round_trip() {
        let frames = vec![
            Frame::Request {
                id: 7,
                method: Method::Standard { t: 100 },
                input: vec![0.25, -1.5, 3.25],
                deadline_ms: None,
            },
            Frame::Request {
                id: 8,
                method: Method::Hybrid { t: 31 },
                input: vec![],
                deadline_ms: None,
            },
            Frame::Request {
                id: 9,
                method: Method::DmBnn { schedule: vec![10, 10, 10] },
                input: vec![f32::MIN_POSITIVE, f32::MAX],
                deadline_ms: Some(250),
            },
            Frame::Response {
                id: 10,
                resp: WireResponse {
                    class: 3,
                    voters: 12,
                    confidence: 0.75,
                    entropy: 1.0625,
                    latency_us: 12345,
                },
            },
            Frame::Error { id: 11, err: ServeError::DimMismatch("dim 3 != 784".into()) },
            Frame::Error { id: 12, err: ServeError::Timeout },
            Frame::Ping { id: 13 },
            Frame::Pong { id: 14 },
            Frame::MetricsRequest { id: 15 },
            Frame::MetricsText { id: 16, text: "{\"requests\":3}".into() },
        ];
        for f in &frames {
            assert_eq!(&round_trip(f), f, "{f:?}");
        }
    }

    #[test]
    fn random_request_frames_round_trip() {
        // Property test over generated frames: ids, methods, lengths and
        // payload bit patterns all survive encode → decode exactly.
        use crate::grng::uniform::{UniformSource, XorShift128Plus};
        let mut r = XorShift128Plus::new(0xF4A3);
        for round in 0..200 {
            let id = ((r.next_f32().to_bits() as u64) << 20) | round;
            let n = (r.next_f32() * 64.0) as usize;
            let input: Vec<f32> = (0..n).map(|_| r.next_f32() * 2.0 - 1.0).collect();
            let method = match round % 3 {
                0 => Method::Standard { t: 1 + (r.next_f32() * 400.0) as usize },
                1 => Method::Hybrid { t: 1 + (r.next_f32() * 400.0) as usize },
                _ => Method::DmBnn {
                    schedule: (0..3).map(|_| 1 + (r.next_f32() * 20.0) as usize).collect(),
                },
            };
            let deadline_ms =
                if round % 2 == 0 { Some((r.next_f32() * 1e6) as u64) } else { None };
            let f = Frame::Request { id, method, input, deadline_ms };
            assert_eq!(round_trip(&f), f, "round {round}");
        }
    }

    #[test]
    fn nonfinite_floats_round_trip_by_bits() {
        let f = Frame::Request {
            id: 1,
            method: Method::Standard { t: 1 },
            input: vec![f32::INFINITY, f32::NEG_INFINITY, -0.0],
            deadline_ms: None,
        };
        let g = round_trip(&f);
        let (Frame::Request { input: a, .. }, Frame::Request { input: b, .. }) = (&f, &g) else {
            panic!("kind changed in flight");
        };
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(a), bits(b));
    }

    fn expect_bad(bytes: &[u8], what: &str) -> ServeError {
        let mut c = Cursor::new(bytes.to_vec());
        match read_frame(&mut c, MAX_FRAME_PAYLOAD, T) {
            Err(e) => e,
            Ok(o) => panic!("{what}: expected rejection, got {o:?}"),
        }
    }

    #[test]
    fn garbage_and_bad_magic_rejected() {
        let e = expect_bad(&[0xDE; 64], "garbage");
        assert!(matches!(e, ServeError::BadRequest(_)), "{e:?}");
        let mut almost = encode(&Frame::Ping { id: 1 });
        almost[0] = b'X';
        let e = expect_bad(&almost, "bad magic");
        assert!(e.to_string().contains("magic"), "{e}");
    }

    #[test]
    fn wrong_version_rejected() {
        let mut bytes = encode(&Frame::Ping { id: 1 });
        bytes[4] = 9;
        let e = expect_bad(&bytes, "version");
        assert!(e.to_string().contains("version"), "{e}");
    }

    #[test]
    fn unknown_kind_rejected() {
        let mut bytes = encode(&Frame::Ping { id: 1 });
        bytes[5] = 200;
        let e = expect_bad(&bytes, "kind");
        assert!(e.to_string().contains("kind"), "{e}");
    }

    #[test]
    fn truncated_header_and_payload_rejected() {
        let bytes = encode(&Frame::Request {
            id: 2,
            method: Method::Standard { t: 3 },
            input: vec![1.0, 2.0],
            deadline_ms: None,
        });
        // cut inside the header and inside the payload
        for cut in [1, HEADER_BYTES - 1, HEADER_BYTES + 3, bytes.len() - 1] {
            let e = expect_bad(&bytes[..cut], "truncation");
            assert!(e.to_string().contains("truncated"), "cut {cut}: {e}");
        }
    }

    #[test]
    fn oversized_frame_rejected_without_allocation() {
        let mut bytes = encode(&Frame::Ping { id: 3 });
        bytes[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        let e = expect_bad(&bytes, "oversized");
        assert!(e.to_string().contains("oversized"), "{e}");
    }

    #[test]
    fn payload_length_lies_are_rejected() {
        // declared input length larger than the actual payload
        let mut bytes = encode(&Frame::Request {
            id: 4,
            method: Method::Standard { t: 3 },
            input: vec![1.0, 2.0],
            deadline_ms: None,
        });
        let body = HEADER_BYTES + 1 + 4; // method tag + t
        bytes[body..body + 4].copy_from_slice(&100u32.to_le_bytes());
        let e = expect_bad(&bytes, "length lie");
        assert!(e.to_string().contains("truncated"), "{e}");

        // trailing junk after a well-formed payload
        let mut bytes = encode(&Frame::Ping { id: 5 });
        let len = bytes.len();
        bytes.extend_from_slice(&[1, 2, 3]);
        bytes[16..20].copy_from_slice(&3u32.to_le_bytes());
        let e = expect_bad(&bytes[..len + 3], "trailing");
        assert!(e.to_string().contains("trailing"), "{e}");
    }

    #[test]
    fn eof_at_boundary_is_clean() {
        let mut c = Cursor::new(Vec::new());
        assert!(matches!(
            read_frame(&mut c, MAX_FRAME_PAYLOAD, T).unwrap(),
            ReadOutcome::Eof
        ));
    }

    #[test]
    fn deadline_gates_the_frame_version() {
        // Deadline-less requests stay byte-for-byte version 1 — an old
        // server keeps accepting them.
        let v1 = Frame::Request {
            id: 1,
            method: Method::Standard { t: 4 },
            input: vec![0.5],
            deadline_ms: None,
        };
        assert_eq!(encode(&v1)[4], PROTO_VERSION);
        // A deadline upgrades the frame to version 2 with a trailing u64.
        let v2 = Frame::Request {
            id: 1,
            method: Method::Standard { t: 4 },
            input: vec![0.5],
            deadline_ms: Some(1500),
        };
        let bytes = encode(&v2);
        assert_eq!(bytes[4], PROTO_VERSION_DEADLINE);
        assert_eq!(bytes.len(), encode(&v1).len() + 8);
        assert_eq!(round_trip(&v2), v2);
        // Non-request frames never leave version 1.
        assert_eq!(encode(&Frame::Ping { id: 3 })[4], PROTO_VERSION);
    }

    #[test]
    fn trailing_deadline_bytes_in_a_v1_frame_are_rejected() {
        // A v1 request must not smuggle the v2 trailing field: without
        // the version stamp those 8 bytes are trailing junk.
        let mut bytes = encode(&Frame::Request {
            id: 6,
            method: Method::Standard { t: 2 },
            input: vec![1.0],
            deadline_ms: Some(99),
        });
        bytes[4] = PROTO_VERSION; // lie about the version
        let e = expect_bad(&bytes, "v1 with deadline bytes");
        assert!(e.to_string().contains("trailing"), "{e}");
    }

    fn round_trip_crc(f: &Frame) -> Frame {
        let bytes = encode_with(f, true);
        let mut c = Cursor::new(bytes);
        match read_frame(&mut c, MAX_FRAME_PAYLOAD, T).expect("decode v3") {
            ReadOutcome::Frame(g) => g,
            other => panic!("expected a frame, got {other:?}"),
        }
    }

    #[test]
    fn crc_frames_round_trip_every_kind() {
        let frames = vec![
            Frame::Request {
                id: 20,
                method: Method::DmBnn { schedule: vec![8, 8, 8] },
                input: vec![0.5, -0.25],
                deadline_ms: Some(750), // deadline still parses after the CRC strips
            },
            Frame::Request {
                id: 21,
                method: Method::Standard { t: 9 },
                input: vec![1.0],
                deadline_ms: None,
            },
            Frame::Response {
                id: 22,
                resp: WireResponse {
                    class: 1,
                    voters: 7,
                    confidence: 0.5,
                    entropy: 0.25,
                    latency_us: 99,
                },
            },
            Frame::Ping { id: 23 },
            Frame::MetricsText { id: 24, text: "{}".into() },
        ];
        for f in &frames {
            let bytes = encode_with(f, true);
            assert_eq!(bytes[4], PROTO_VERSION_CRC, "{f:?}");
            assert_eq!(bytes.len(), encode_with(f, false).len() + 4, "{f:?}");
            assert_eq!(&round_trip_crc(f), f, "{f:?}");
        }
    }

    #[test]
    fn crc_detects_any_flipped_payload_byte() {
        let f = Frame::Request {
            id: 30,
            method: Method::Standard { t: 5 },
            input: vec![0.125, 2.5, -3.0],
            deadline_ms: Some(100),
        };
        let bytes = encode_with(&f, true);
        for i in HEADER_BYTES..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0xFF;
            let e = expect_bad(&bad, "payload flip");
            assert!(e.to_string().contains("checksum"), "byte {i}: {e}");
        }
        // The same flip in a v1 frame parses "successfully" — the gap
        // v3 exists to close.
        let v1 = encode_with(&Frame::Ping { id: 31 }, false);
        assert_eq!(v1.len(), HEADER_BYTES, "ping has no payload to flip");
    }

    #[test]
    fn v3_frame_shorter_than_its_checksum_is_rejected() {
        let mut bytes = encode_with(&Frame::Ping { id: 32 }, true);
        assert_eq!(bytes.len(), HEADER_BYTES + 4); // payload is just the CRC
        bytes[4] = PROTO_VERSION_CRC;
        bytes[16..20].copy_from_slice(&2u32.to_le_bytes());
        let e = expect_bad(&bytes[..HEADER_BYTES + 2], "short v3");
        assert!(e.to_string().contains("checksum"), "{e}");
    }

    #[test]
    fn error_frames_preserve_wire_codes() {
        for err in [
            ServeError::bad_request("x"),
            ServeError::DimMismatch("y".into()),
            ServeError::Overloaded,
            ServeError::Timeout,
            ServeError::ShuttingDown,
            ServeError::internal("z"),
        ] {
            let f = Frame::Error { id: 1, err: err.clone() };
            let Frame::Error { err: back, .. } = round_trip(&f) else {
                panic!("kind changed");
            };
            assert_eq!(back.code(), err.code());
        }
    }
}
