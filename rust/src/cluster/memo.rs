//! Response-level memoization: whole-request answers above the (β, η)
//! cache.
//!
//! Under `SeedSchedule::ContentHash` with single-request evaluation units
//! (the cluster router's shape), a request's answer is a **pure function**
//! of `(input bits, method)`: the uncertainty banks derive from the
//! content hash, the dataflow is deterministic, and no engine call history
//! leaks in.  A fully-identical repeat can therefore skip the entire voter
//! sweep — not just the deterministic precompute the `nn::dmcache` level
//! memoizes — and replay the stored logits bit-exactly.
//!
//! # Key scheme and verification
//!
//! Entries are keyed by [`request_key`] — FNV-1a over the method's
//! discriminant/parameters and the input's f32 bit patterns, finalized
//! with `mix64` (the same scheme `nn::dmcache` uses, and the same hash the
//! cluster router shards requests by).  The full key (method + input
//! vector) is stored in the entry and compared on lookup, so a hash
//! collision degrades to a miss, never a wrong response.
//!
//! # Bounding and eviction
//!
//! Same discipline as `nn::dmcache`: a byte budget split over mutex
//! shards, each running CLOCK second-chance eviction over its insertion
//! ring, entries larger than one shard's budget simply not cached.
//!
//! # Op accounting
//!
//! A stored response carries the *logical* MUL/ADD counts of computing it.
//! On a hit the caller books those counts as logical-but-avoided
//! ([`OpCounter::avoided`] semantics): logical totals stay bit-identical
//! to memo-off runs while `muls_avoided`/`adds_avoided` — and the memo's
//! own [`MemoStats`] — report the skipped voter sweep distinctly.
//!
//! [`OpCounter::avoided`]: crate::opcount::counter::OpCounter::avoided

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::nn::bnn::Method;
use crate::util::hash::{fnv1a_f32s, fnv1a_u64, mix64, FNV_OFFSET};

/// Environment variable read by [`MemoConfig::from_env`].
pub const MEMO_MB_ENV: &str = "BAYESDM_MEMO_MB";

const DEFAULT_SHARDS: usize = 8;

/// Estimated fixed overhead per entry (map slot, ring slot, `Arc` and vec
/// headers, stored method) — counted against the byte budget.
const ENTRY_OVERHEAD: usize = 160;

/// Response-memo sizing knobs.  `capacity_bytes == 0` disables the memo —
/// the default, preserving pre-memo behavior exactly.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MemoConfig {
    /// Total byte budget across all shards (0 = disabled).
    pub capacity_bytes: usize,
    /// Lock shards; responses are small, so no shard floor is needed.
    pub shards: usize,
}

impl MemoConfig {
    /// Memo off (the default).
    pub fn disabled() -> Self {
        Self { capacity_bytes: 0, shards: DEFAULT_SHARDS }
    }

    /// Memo on with a budget in MiB.
    pub fn with_mb(mb: usize) -> Self {
        Self { capacity_bytes: mb << 20, shards: DEFAULT_SHARDS }
    }

    /// Honor the `BAYESDM_MEMO_MB` environment toggle (the CI cluster leg
    /// runs the suite memo-default-on); disabled when unset or unparsable.
    pub fn from_env() -> Self {
        match std::env::var(MEMO_MB_ENV) {
            Ok(v) => match v.trim().parse::<usize>() {
                Ok(mb) if mb > 0 => Self::with_mb(mb),
                _ => Self::disabled(),
            },
            Err(_) => Self::disabled(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.capacity_bytes > 0
    }
}

impl Default for MemoConfig {
    fn default() -> Self {
        Self::disabled()
    }
}

/// The routing/memo key of one request: FNV-1a over the method identity
/// and the input's f32 bit patterns, finalized with `mix64`.  Two requests
/// collide iff method and input bits are identical — exactly the equality
/// under which a `ContentHash` response is reusable.  The cluster router
/// shards by the same key, so repeats always land on the same shard.
pub fn request_key(method: &Method, x: &[f32]) -> u64 {
    let mut state = match method {
        Method::Standard { t } => fnv1a_u64(fnv1a_u64(FNV_OFFSET, 1), *t as u64),
        Method::Hybrid { t } => fnv1a_u64(fnv1a_u64(FNV_OFFSET, 2), *t as u64),
        Method::DmBnn { schedule } => {
            let mut s = fnv1a_u64(FNV_OFFSET, 3);
            s = fnv1a_u64(s, schedule.len() as u64);
            for &k in schedule {
                s = fnv1a_u64(s, k as u64);
            }
            s
        }
    };
    state = fnv1a_u64(state, x.len() as u64);
    mix64(fnv1a_f32s(state, x))
}

/// One memoized response: the request's flat voter-logit stack plus the
/// logical op counts of computing it.
#[derive(Debug, Clone, PartialEq)]
pub struct MemoResponse {
    /// Flat `voters × classes` logits (one `LogitBatch` input window).
    pub flat: Vec<f32>,
    pub voters: usize,
    pub classes: usize,
    /// Logical MULs of the full (un-memoized) evaluation.
    pub muls: u64,
    /// Logical ADDs of the full (un-memoized) evaluation.
    pub adds: u64,
}

struct Entry {
    method: Method,
    x: Vec<f32>,
    response: Arc<MemoResponse>,
    referenced: bool,
    bytes: usize,
}

#[derive(Default)]
struct Shard {
    map: HashMap<u64, Entry>,
    /// CLOCK ring of insertion-ordered keys (stale keys skipped on sweep).
    ring: VecDeque<u64>,
    bytes: usize,
}

impl Shard {
    /// Evict one unreferenced entry (second-chance sweep); false when the
    /// shard has nothing evictable.  Bounded exactly like the dmcache
    /// sweep: after one full pass every referenced bit is clear.
    fn clock_evict(&mut self) -> bool {
        enum Sweep {
            Stale,
            SecondChance,
            Evict,
        }
        let mut budget = 2 * self.ring.len() + 1;
        while budget > 0 {
            budget -= 1;
            let key = match self.ring.pop_front() {
                Some(k) => k,
                None => return false,
            };
            let action = match self.map.get_mut(&key) {
                None => Sweep::Stale, // stale (overwritten) ring slot
                Some(e) if e.referenced => {
                    e.referenced = false;
                    Sweep::SecondChance
                }
                Some(_) => Sweep::Evict,
            };
            match action {
                Sweep::Stale => {}
                Sweep::SecondChance => self.ring.push_back(key),
                Sweep::Evict => {
                    if let Some(e) = self.map.remove(&key) {
                        self.bytes -= e.bytes;
                    }
                    return true;
                }
            }
        }
        false
    }
}

/// Aggregate memo counters, reported through `MetricsSummary::memo` and
/// the serve/eval CLI.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemoStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
    /// Live entries across all shards.
    pub entries: u64,
    /// Accounted bytes across all shards.
    pub bytes: u64,
    /// Logical MULs skipped by hits — whole voter sweeps, not just the
    /// precompute the decomposition cache saves.
    pub muls_avoided: u64,
    /// Logical ADDs skipped by hits.
    pub adds_avoided: u64,
}

impl std::fmt::Display for MemoStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "hits={} misses={} evictions={} entries={} bytes={} muls_avoided={} adds_avoided={}",
            self.hits,
            self.misses,
            self.evictions,
            self.entries,
            self.bytes,
            self.muls_avoided,
            self.adds_avoided,
        )
    }
}

/// The sharded, bounded-memory response memo.
pub struct ResponseMemo {
    shards: Vec<Mutex<Shard>>,
    shard_budget: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    insertions: AtomicU64,
    evictions: AtomicU64,
    muls_avoided: AtomicU64,
    adds_avoided: AtomicU64,
}

impl ResponseMemo {
    pub fn new(cfg: &MemoConfig) -> Self {
        let nshards = cfg.shards.max(1);
        Self {
            shards: (0..nshards).map(|_| Mutex::new(Shard::default())).collect(),
            shard_budget: cfg.capacity_bytes / nshards,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            muls_avoided: AtomicU64::new(0),
            adds_avoided: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: u64) -> &Mutex<Shard> {
        &self.shards[(key % self.shards.len() as u64) as usize]
    }

    fn entry_bytes(x_len: usize, flat_len: usize) -> usize {
        (x_len + flat_len) * std::mem::size_of::<f32>() + ENTRY_OVERHEAD
    }

    /// Probe for the memoized response of `(method, x)`.  A hit bumps the
    /// referenced bit and books the whole stored evaluation as avoided.
    pub fn lookup(&self, method: &Method, x: &[f32]) -> Option<Arc<MemoResponse>> {
        let key = request_key(method, x);
        let found = {
            let mut shard = self.shard(key).lock().unwrap();
            match shard.map.get_mut(&key) {
                Some(e) if e.method == *method && slices_bit_equal(&e.x, x) => {
                    e.referenced = true;
                    Some(e.response.clone())
                }
                _ => None,
            }
        };
        match found {
            Some(r) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                self.muls_avoided.fetch_add(r.muls, Ordering::Relaxed);
                self.adds_avoided.fetch_add(r.adds, Ordering::Relaxed);
                Some(r)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert a freshly computed response, evicting under pressure.
    /// Responses larger than one shard's budget are not cached.
    pub fn insert(&self, method: &Method, x: &[f32], response: MemoResponse) {
        let bytes = Self::entry_bytes(x.len(), response.flat.len());
        if bytes > self.shard_budget {
            return;
        }
        let key = request_key(method, x);
        let mut evicted = 0u64;
        {
            let mut shard = self.shard(key).lock().unwrap();
            while shard.bytes + bytes > self.shard_budget {
                if !shard.clock_evict() {
                    break;
                }
                evicted += 1;
            }
            if shard.bytes + bytes > self.shard_budget {
                self.evictions.fetch_add(evicted, Ordering::Relaxed);
                return;
            }
            let entry = Entry {
                method: method.clone(),
                x: x.to_vec(),
                response: Arc::new(response),
                referenced: false,
                bytes,
            };
            if let Some(old) = shard.map.insert(key, entry) {
                shard.bytes -= old.bytes;
            }
            shard.bytes += bytes;
            shard.ring.push_back(key);
        }
        self.insertions.fetch_add(1, Ordering::Relaxed);
        self.evictions.fetch_add(evicted, Ordering::Relaxed);
    }

    /// Counter snapshot (entry/byte totals take each shard lock briefly).
    pub fn stats(&self) -> MemoStats {
        let (mut entries, mut bytes) = (0u64, 0u64);
        for s in &self.shards {
            let s = s.lock().unwrap();
            entries += s.map.len() as u64;
            bytes += s.bytes as u64;
        }
        MemoStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            insertions: self.insertions.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries,
            bytes,
            muls_avoided: self.muls_avoided.load(Ordering::Relaxed),
            adds_avoided: self.adds_avoided.load(Ordering::Relaxed),
        }
    }
}

impl std::fmt::Debug for ResponseMemo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ResponseMemo")
            .field("shards", &self.shards.len())
            .field("shard_budget", &self.shard_budget)
            .field("stats", &self.stats())
            .finish()
    }
}

/// Bit-pattern equality, matching [`request_key`]'s hashing (`0.0 !=
/// -0.0`, `NaN == NaN` for identical payloads) — also the router's
/// intra-batch duplicate test, so grouping agrees with memo keying.
pub(crate) fn slices_bit_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(p, q)| p.to_bits() == q.to_bits())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn response(flat: &[f32]) -> MemoResponse {
        MemoResponse { flat: flat.to_vec(), voters: 2, classes: flat.len() / 2, muls: 10, adds: 6 }
    }

    #[test]
    fn miss_then_hit_roundtrip_with_avoided_ops() {
        let m = ResponseMemo::new(&MemoConfig::with_mb(1));
        let method = Method::Standard { t: 2 };
        let x = vec![1.0f32, 2.0];
        assert!(m.lookup(&method, &x).is_none());
        m.insert(&method, &x, response(&[0.5, 0.25, 0.125, 0.0625]));
        let got = m.lookup(&method, &x).expect("hit");
        assert_eq!(got.flat, vec![0.5, 0.25, 0.125, 0.0625]);
        let s = m.stats();
        assert_eq!((s.hits, s.misses, s.insertions, s.entries), (1, 1, 1, 1));
        assert_eq!((s.muls_avoided, s.adds_avoided), (10, 6));
        assert!(s.bytes > 0);
    }

    #[test]
    fn key_separates_method_and_input() {
        let m = ResponseMemo::new(&MemoConfig::with_mb(1));
        let x = vec![1.0f32, 2.0];
        m.insert(&Method::Standard { t: 2 }, &x, response(&[1.0, 2.0]));
        assert!(m.lookup(&Method::Standard { t: 3 }, &x).is_none(), "other t");
        assert!(m.lookup(&Method::Hybrid { t: 2 }, &x).is_none(), "other method");
        assert!(m.lookup(&Method::Standard { t: 2 }, &[1.0, 2.5]).is_none(), "other input");
        assert!(m.lookup(&Method::Standard { t: 2 }, &x).is_some());
    }

    #[test]
    fn request_key_separates_dm_schedules_and_matches_itself() {
        let x = vec![0.5f32; 4];
        let a = request_key(&Method::DmBnn { schedule: vec![2, 3] }, &x);
        let b = request_key(&Method::DmBnn { schedule: vec![3, 2] }, &x);
        assert_ne!(a, b);
        assert_eq!(a, request_key(&Method::DmBnn { schedule: vec![2, 3] }, &x));
        // standard t=6 and dm [6] must not collide even with equal voters
        assert_ne!(
            request_key(&Method::Standard { t: 6 }, &x),
            request_key(&Method::DmBnn { schedule: vec![6] }, &x)
        );
    }

    #[test]
    fn eviction_keeps_memory_bounded_and_protects_hot_entries() {
        let entry = ResponseMemo::entry_bytes(4, 8);
        let cfg = MemoConfig { capacity_bytes: 3 * entry, shards: 1 };
        let m = ResponseMemo::new(&cfg);
        let method = Method::Standard { t: 2 };
        let hot = vec![9.0f32; 4];
        m.insert(&method, &hot, response(&[1.0; 8]));
        for i in 0..24 {
            assert!(m.lookup(&method, &hot).is_some(), "hot entry evicted at {i}");
            let x: Vec<f32> = (0..4).map(|j| (i * 4 + j) as f32).collect();
            m.insert(&method, &x, response(&[1.0; 8]));
            assert!(m.stats().bytes <= cfg.capacity_bytes as u64, "budget overrun");
        }
        let s = m.stats();
        assert!(s.evictions > 0);
        assert!(s.entries <= 3);
        assert!(m.lookup(&method, &hot).is_some());
    }

    #[test]
    fn zero_capacity_memoizes_nothing() {
        let m = ResponseMemo::new(&MemoConfig::disabled());
        let method = Method::Hybrid { t: 2 };
        let x = vec![1.0f32; 3];
        m.insert(&method, &x, response(&[1.0, 2.0]));
        assert!(m.lookup(&method, &x).is_none());
        assert_eq!(m.stats().entries, 0);
    }

    #[test]
    fn config_env_and_defaults() {
        assert!(!MemoConfig::disabled().enabled());
        assert!(MemoConfig::with_mb(4).enabled());
        assert_eq!(MemoConfig::with_mb(2).capacity_bytes, 2 << 20);
        assert_eq!(MemoConfig::default(), MemoConfig::disabled());
    }

    #[test]
    fn memo_is_send_and_sync() {
        fn assert_sync<T: Send + Sync>() {}
        assert_sync::<ResponseMemo>();
    }
}
