//! Cluster subsystem — sharded multi-engine serving over shared caching
//! services.
//!
//! The paper's DM strategy wins by memoizing the deterministic half of
//! every Gaussian-weight multiply; this module lifts that principle to
//! the serving tier, the way VIBNN/Bayes2IMC-style accelerators share
//! weight/feature reuse across compute units instead of duplicating it:
//!
//! * [`router`]       — [`ClusterRouter`]: hash-routes each request over N
//!   `Engine` shards behind bounded per-shard queues with aggregate
//!   backpressure; implements `InferenceBackend`, so the server and CLI
//!   run unchanged on top.  Results are bit-identical for every shard
//!   count (shard engines run per-request `ContentHash` evaluation).
//! * [`cacheservice`] — [`CacheService`]: the (β, η) decomposition cache
//!   as a first-class shared service — ONE byte budget and one set of
//!   mutex shards re-partitioned across engines instead of duplicated per
//!   engine, with per-engine hit/miss attribution.
//! * [`memo`]         — [`ResponseMemo`]: response-level memoization above
//!   the (β, η) cache; a fully-identical `(input, method)` request is a
//!   pure function under `ContentHash`, so exact repeats skip the entire
//!   voter sweep and replay stored logits bit-exactly.
//! * [`snapshot`]     — cache warm-up/persistence across restarts:
//!   versioned, checksummed, model-fingerprint-gated snapshot files that
//!   degrade to cold misses, never wrong results.
//!
//! Deployment shape is one knob set on `EngineConfig` (`shards`, `memo`,
//! `snapshot` — CLI `--shards`/`--memo-mb`/`--cache-snapshot`, env
//! `BAYESDM_SHARDS`/`BAYESDM_MEMO_MB`), all off/1 by default so existing
//! single-engine invocations are byte-identical.

pub mod cacheservice;
pub mod memo;
pub mod router;
pub mod snapshot;

pub use cacheservice::{CacheService, ShardBreakdown};
pub use memo::{MemoConfig, MemoStats, ResponseMemo};
pub use router::ClusterRouter;
pub use snapshot::SnapshotReport;
