//! The decomposition cache as a first-class shared service.
//!
//! A single-engine deployment owns a private `DmCache`; N engines each
//! owning one would duplicate every hot (β, η) entry N times and split the
//! operator's byte budget into N fixed silos.  [`CacheService`] instead
//! builds **one** `DmCache` — one byte budget, one set of mutex shards —
//! and hands each engine a [`CacheLease`] over it.
//!
//! # Why sharing beats partitioning
//!
//! The cache's internal mutex shards are selected by key hash, not by
//! engine, so N engines probing one shared cache contend exactly as much
//! as N request threads probing a private cache did — the 16-way shard
//! partition is *re-partitioned across engines* rather than duplicated
//! per engine.  Capacity-wise, a shared budget B behaves like the best
//! case of per-engine budgets B/N: a hot entry occupies one slot total
//! instead of one per engine that sees it, and skewed traffic (all hot
//! inputs routed to few engines) cannot strand budget in idle silos.
//!
//! # Attribution
//!
//! The shared cache's counters are the aggregate.  Each lease carries its
//! own [`ClientCounters`], so hit/miss/avoided traffic is additionally
//! attributed per engine and surfaces as the per-shard breakdown in
//! `MetricsSummary` (see [`ShardBreakdown`]).

use std::sync::Arc;

use crate::nn::dmcache::{
    AttributionStats, CacheConfig, CacheLease, CacheStats, ClientCounters, DmCache,
};

/// One shared decomposition cache plus per-engine attribution slots.
pub struct CacheService {
    cache: Arc<DmCache>,
    leases: Vec<CacheLease>,
}

impl CacheService {
    /// One cache with the **whole** `cfg` budget, leased to `engines`
    /// clients (at least one).
    pub fn new(cfg: &CacheConfig, engines: usize) -> Self {
        let cache = Arc::new(DmCache::new(cfg));
        let mut leases = Vec::with_capacity(engines.max(1));
        for _ in 0..engines.max(1) {
            let attribution = Arc::new(ClientCounters::new());
            leases.push(CacheLease { cache: cache.clone(), attribution });
        }
        Self { cache, leases }
    }

    pub fn engines(&self) -> usize {
        self.leases.len()
    }

    /// Engine `i`'s lease: the shared cache + that engine's counters.
    pub fn lease(&self, engine: usize) -> CacheLease {
        self.leases[engine].clone()
    }

    /// The shared cache itself (snapshot save/load operates on this).
    pub fn cache(&self) -> &DmCache {
        &self.cache
    }

    /// Aggregate counters of the shared cache.
    pub fn stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Per-engine attribution snapshots, indexed by engine.
    pub fn per_engine(&self) -> Vec<AttributionStats> {
        self.leases.iter().map(|l| l.attribution.snapshot()).collect()
    }
}

impl std::fmt::Debug for CacheService {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CacheService")
            .field("engines", &self.leases.len())
            .field("stats", &self.stats())
            .finish()
    }
}

/// One shard's slice of a cluster's serving traffic: requests dispatched
/// to it plus its attributed share of the shared cache's counters
/// (zeroed when the deployment runs cache-less).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardBreakdown {
    pub shard: usize,
    pub requests: u64,
    pub cache: AttributionStats,
}

impl std::fmt::Display for ShardBreakdown {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "shard{}[requests={} {}]", self.shard, self.requests, self.cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::dmcache::{CacheView, Decomp};

    fn decomp(m: usize, n: usize, fill: f32) -> Arc<Decomp> {
        Arc::new(Decomp { beta: vec![fill; m * n], eta: vec![fill; m] })
    }

    #[test]
    fn one_budget_shared_across_leases() {
        let svc = CacheService::new(&CacheConfig::with_mb(1), 3);
        assert_eq!(svc.engines(), 3);
        let x = vec![1.0f32, 2.0];
        let a = svc.lease(0);
        let b = svc.lease(1);
        // engine 0 inserts, engine 1 hits the SAME entry — no duplication
        let va = CacheView::attributed(&a.cache, 7, &a.attribution);
        let vb = CacheView::attributed(&b.cache, 7, &b.attribution);
        assert!(va.lookup(0, &x).is_none());
        va.insert(0, &x, &decomp(2, 2, 0.5));
        assert!(vb.lookup(0, &x).is_some(), "cross-engine reuse");
        assert_eq!(svc.stats().entries, 1, "one entry total, not one per engine");
        let per = svc.per_engine();
        assert_eq!((per[0].hits, per[0].misses), (0, 1));
        assert_eq!((per[1].hits, per[1].misses), (1, 0));
        assert_eq!(per[2], AttributionStats::default());
        // aggregate = sum of attributions
        let total = svc.stats();
        assert_eq!(total.hits, per.iter().map(|p| p.hits).sum::<u64>());
        assert_eq!(total.misses, per.iter().map(|p| p.misses).sum::<u64>());
    }

    #[test]
    fn breakdown_renders_compactly() {
        let b = ShardBreakdown {
            shard: 2,
            requests: 9,
            cache: AttributionStats { hits: 3, misses: 1, muls_avoided: 24, adds_avoided: 8 },
        };
        let s = b.to_string();
        assert!(s.starts_with("shard2[requests=9"), "{s}");
        assert!(s.contains("hits=3"), "{s}");
    }
}
