//! `ClusterRouter` — shard-aware multi-engine serving behind the same
//! [`InferenceBackend`] slot a single `Engine` plugs into, so the
//! router/batcher (`coordinator::server`) and the CLI run unchanged on
//! top of an N-engine deployment.
//!
//! # Topology
//!
//! One `Engine` per shard, each fed by a dedicated worker thread behind a
//! **bounded** `sync_channel` queue.  A request is routed by
//! `request_key(method, input) % shards` — the same content hash the
//! response memo keys on — so identical requests always land on the same
//! shard and hot (β, η) entries cluster there even before the shared
//! cache smooths it out.  When a shard's queue fills, `evaluate` blocks on
//! the send: callers (the server's dispatch workers) slow down together,
//! which is the aggregate backpressure — the cluster can never buffer
//! unboundedly ahead of its slowest shard.
//!
//! # Determinism: why shard count is invisible in the results
//!
//! Every shard engine is forced onto [`SeedSchedule::ContentHash`] and
//! evaluates **one request per batch**: request `x`'s banks derive from
//! `split_seed(seed, hash([x]))`, a pure function of `(seed, x)` shared
//! by all shards.  Routing therefore only chooses *where* a request runs,
//! never *what* it computes — N-shard logits and logical op counts are
//! bit-identical to the 1-shard deployment (`tests/cluster_parity.rs`),
//! which is also exactly the purity that makes response memoization sound.
//!
//! # Shared services
//!
//! All shards lease one [`CacheService`] (one decomposition-cache budget,
//! per-shard attribution) and sit under one optional [`ResponseMemo`]:
//! an exact `(input, method)` repeat skips the entire voter sweep and
//! replays the memoized logits, booking the skipped work as
//! logical-but-avoided ops.  `EngineConfig::snapshot` persists the shared
//! cache across restarts (`cluster::snapshot`): loaded at construction,
//! saved on [`ClusterRouter::save_snapshot`] and on drop.
//!
//! # Supervision: shards are a fault domain, not a fate-sharing unit
//!
//! Each shard worker runs its evaluation under `catch_unwind`; a panic
//! (a kernel bug, or the armed `worker.panic` fault point) answers the
//! in-flight request with a typed failure marker and retires the thread.
//! `evaluate` supervises: on a failure reply it respawns the shard — same
//! engine `Arc`, same `ContentHash` seed schedule — and resubmits the
//! slot; a shard that stops answering entirely (`shard.stall`) trips a
//! watchdog ([`watchdog_from_env`], `BAYESDM_WATCHDOG_MS`) with the same
//! heal-and-resubmit recovery.  Because each answer is a pure function of
//! `(seed, input)`, a resubmitted request — and even a late duplicate
//! reply from a stalled-but-alive worker — is bit-identical to the answer
//! the dead shard would have produced, so recovery is invisible in the
//! results (chaos-tested in `tests/chaos.rs`).  Restarts are counted in
//! `MetricsSummary::shard_restarts`, caught panics in `panics_caught`.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::engine::{
    accuracy_over, validate_request, Engine, EngineConfig, SeedSchedule,
};
use crate::coordinator::metrics::{Metrics, MetricsSummary};
use crate::coordinator::plan::InferenceMethod;
use crate::coordinator::server::InferenceBackend;
use crate::coordinator::vote;
use crate::nn::batch::BatchResult;
use crate::nn::bnn::{BnnModel, Method};
use crate::nn::dmcache::CacheConfig;
use crate::nn::plan::LogitBatch;
use crate::opcount::counter::OpCounter;
use crate::serve::{RetryPolicy, ServeError};
use crate::trace::{self, EventId};
use crate::util::fault;

use super::cacheservice::{CacheService, ShardBreakdown};
use super::memo::{request_key, slices_bit_equal, MemoConfig, MemoResponse, ResponseMemo};
use super::snapshot::{self, SnapshotReport};

/// Environment variable read by [`shards_from_env`] (the CI cluster leg
/// sets it so default-config deployments exercise multi-shard routing).
pub const SHARDS_ENV: &str = "BAYESDM_SHARDS";

/// `BAYESDM_SHARDS` default for `EngineConfig::shards`: 1 (single engine,
/// byte-identical to pre-cluster behavior) when unset or unparsable.
pub fn shards_from_env() -> usize {
    match std::env::var(SHARDS_ENV) {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => 1,
        },
        Err(_) => 1,
    }
}

/// Per-shard request queue depth.  Small enough that backpressure reaches
/// the server's admission queue quickly, large enough to keep a shard fed
/// across scheduling hiccups.
pub const SHARD_QUEUE_DEPTH: usize = 256;

/// Resubmissions one request slot may consume across shard failures
/// before `evaluate` gives up with a typed `Internal` error.  Failures
/// are counted per slot, so one crash-looping shard cannot starve a
/// request forever, and a healthy run never touches the budget.
pub const MAX_SLOT_RETRIES: u32 = 8;

/// Environment variable overriding the shard watchdog (milliseconds).
/// A shard that produces no reply for a whole watchdog period while work
/// is pending is presumed wedged and is respawned.  The 30 s default is
/// far above any legitimate single-request evaluation; tests and chaos
/// runs shrink it.
pub const WATCHDOG_ENV: &str = "BAYESDM_WATCHDOG_MS";

/// `BAYESDM_WATCHDOG_MS` with a 30 s default; unset, unparsable or zero
/// values fall back to the default.
pub fn watchdog_from_env() -> Duration {
    let ms = std::env::var(WATCHDOG_ENV)
        .ok()
        .and_then(|v| v.trim().parse::<u64>().ok())
        .filter(|&ms| ms > 0)
        .unwrap_or(30_000);
    Duration::from_millis(ms)
}

struct ShardJob {
    slot: usize,
    input: Vec<f32>,
    method: Method,
    respond: mpsc::Sender<ShardReply>,
}

struct ShardReply {
    slot: usize,
    /// `Err` marks a caught worker panic while evaluating this slot: the
    /// worker answered (so the caller can recover immediately instead of
    /// waiting out the watchdog) and then retired its thread.
    outcome: Result<(Vec<f32>, OpCounter), ()>,
}

/// One shard's supervised serving lane: the live queue sender, the worker
/// thread, and a generation counter that de-duplicates concurrent heal
/// attempts (every observer of generation `g`'s failure races to heal;
/// only the first wins, the rest see `g+1` and stand down).
struct Lane {
    tx: SyncSender<ShardJob>,
    handle: Option<JoinHandle<()>>,
    generation: u64,
}

/// Supervision state for one dispatched representative slot: where it
/// ran, which lane generation accepted it (the heal guard), and how much
/// of its [`MAX_SLOT_RETRIES`] budget is spent.
#[derive(Clone, Copy)]
struct PendingSlot {
    shard: usize,
    generation: u64,
    attempts: u32,
}

/// Spawn one shard worker: evaluate jobs one at a time under
/// `catch_unwind`, reply `Err(())` and retire on a caught panic.  The
/// `worker.panic` and `shard.stall` fault points live here — inside the
/// unwind barrier and under the caller's watchdog respectively — so chaos
/// runs exercise exactly the recovery paths real faults would.
fn spawn_shard_worker(
    shard: usize,
    generation: u64,
    engine: Arc<Engine>,
    rx: Receiver<ShardJob>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("bayesdm-shard-{shard}-g{generation}"))
        .spawn(move || {
            while let Ok(job) = rx.recv() {
                let ShardJob { slot, input, method, respond } = job;
                if trace::armed() {
                    trace::emit(EventId::ShardDequeue, shard as u64, slot as u64, generation);
                }
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    fault::maybe_panic("worker.panic");
                    if let Some(ms) = fault::fire_ms("shard.stall") {
                        std::thread::sleep(Duration::from_millis(ms));
                    }
                    let res = engine.evaluate_batch(std::slice::from_ref(&input), &method);
                    (res.logits.input(0).flat().to_vec(), res.ops)
                }));
                match outcome {
                    Ok(reply) => {
                        let _ = respond.send(ShardReply { slot, outcome: Ok(reply) });
                    }
                    Err(_) => {
                        // Answer first (fast resubmit), then retire: the
                        // supervisor respawns this shard on the same
                        // engine, so the retried answer is bit-identical
                        // to what this thread would have produced.
                        let _ = respond.send(ShardReply { slot, outcome: Err(()) });
                        break;
                    }
                }
            }
        })
        .expect("spawn shard worker")
}

/// The shard-aware multi-engine backend.
pub struct ClusterRouter {
    engines: Vec<Arc<Engine>>,
    lanes: Vec<Mutex<Lane>>,
    /// Watchdog period for wedged-shard detection (see [`WATCHDOG_ENV`]).
    watchdog: Duration,
    /// Jobs actually dispatched to each shard for computation (memo hits
    /// and intra-batch duplicate replays are not counted — their saving
    /// shows up in the memo stats and the `*_avoided` op counters).
    dispatched: Vec<AtomicU64>,
    /// Consecutive heals per shard since its last accepted dispatch —
    /// drives the crash-loop respawn backoff (see [`heal_backoff`]).
    heal_streaks: Vec<AtomicU32>,
    memo: Option<ResponseMemo>,
    service: Option<CacheService>,
    snapshot_path: Option<String>,
    load_report: Option<SnapshotReport>,
    /// Total dispatched count at the last successful snapshot save
    /// (`u64::MAX` = never saved) — lets drop skip a second identical
    /// write right after an explicit `save_snapshot`.
    saved_version: AtomicU64,
    fingerprint: u64,
    input_dim: usize,
    classes: usize,
    num_layers: usize,
    pub metrics: Arc<Metrics>,
}

impl ClusterRouter {
    /// Build an N-shard deployment from one model and one config.
    /// `cfg.shards` engines are spawned (each with its own copy of the
    /// posterior), `cfg.cache` becomes ONE shared [`CacheService`] budget,
    /// `cfg.memo` the response memo, `cfg.snapshot` the persistence path
    /// (loaded here, fingerprint-gated).  Shard engines always run
    /// [`SeedSchedule::ContentHash`] — see the module docs for why that is
    /// required, not a preference.
    ///
    /// Sizing note: shard engines evaluate one request per batch, which
    /// clamps their scoped pool to a single thread — `cfg.workers` is
    /// inherited but inert on the cluster path, so an N-shard deployment
    /// runs ~N compute threads (one per shard worker), not N × workers.
    pub fn new(model: BnnModel, cfg: EngineConfig) -> Self {
        let shards = cfg.shards.max(1);
        let fingerprint = model.fingerprint();
        let input_dim = model.input_dim();
        let classes = model.output_dim();
        let num_layers = model.num_layers();

        let service = cfg.cache.enabled().then(|| CacheService::new(&cfg.cache, shards));
        let memo = cfg.memo.enabled().then(|| ResponseMemo::new(&cfg.memo));
        let snapshot_path = cfg.snapshot.clone();
        let load_report = match (&service, &snapshot_path) {
            (Some(svc), Some(path)) => {
                Some(snapshot::load(svc.cache(), fingerprint, Path::new(path)))
            }
            _ => None,
        };

        let mut engines = Vec::with_capacity(shards);
        let mut lanes = Vec::with_capacity(shards);
        for i in 0..shards {
            let shard_cfg = EngineConfig {
                // the shard leases the shared cache below; a private one
                // would re-introduce exactly the duplication this solves
                cache: CacheConfig::disabled(),
                seed_schedule: SeedSchedule::ContentHash,
                shards: 1,
                memo: MemoConfig::disabled(),
                snapshot: None,
                ..cfg.clone()
            };
            let lease = service.as_ref().map(|s| s.lease(i));
            let engine = Arc::new(Engine::with_cache_lease(model.clone(), shard_cfg, lease));
            let (tx, rx) = mpsc::sync_channel::<ShardJob>(SHARD_QUEUE_DEPTH);
            let handle = spawn_shard_worker(i, 0, engine.clone(), rx);
            engines.push(engine);
            lanes.push(Mutex::new(Lane { tx, handle: Some(handle), generation: 0 }));
        }

        Self {
            engines,
            lanes,
            watchdog: watchdog_from_env(),
            dispatched: (0..shards).map(|_| AtomicU64::new(0)).collect(),
            heal_streaks: (0..shards).map(|_| AtomicU32::new(0)).collect(),
            memo,
            service,
            snapshot_path,
            load_report,
            saved_version: AtomicU64::new(u64::MAX),
            fingerprint,
            input_dim,
            classes,
            num_layers,
            metrics: Arc::new(Metrics::new()),
        }
    }

    pub fn shards(&self) -> usize {
        self.engines.len()
    }

    pub fn input_dim(&self) -> usize {
        self.input_dim
    }

    pub fn output_dim(&self) -> usize {
        self.classes
    }

    /// What `--cache-snapshot` loading found at construction (`None` when
    /// no snapshot was configured or the cache is disabled).
    pub fn snapshot_load_report(&self) -> Option<&SnapshotReport> {
        self.load_report.as_ref()
    }

    /// Total jobs dispatched so far — the dirty marker for snapshot
    /// saves (cache entries only appear through dispatched computation).
    fn traffic_version(&self) -> u64 {
        self.dispatched.iter().map(|d| d.load(Ordering::Relaxed)).sum()
    }

    /// Persist the shared cache to the configured snapshot path now.
    /// `None` when no path or no cache is configured.  Drop saves too,
    /// but only if traffic arrived after the last successful save, so a
    /// clean CLI shutdown does not write the same snapshot twice.
    pub fn save_snapshot(&self) -> Option<Result<SnapshotReport, ServeError>> {
        let (svc, path) = match (&self.service, &self.snapshot_path) {
            (Some(svc), Some(path)) => (svc, path),
            _ => return None,
        };
        let version = self.traffic_version();
        let result = snapshot::save(svc.cache(), self.fingerprint, Path::new(path));
        if result.is_ok() {
            self.saved_version.store(version, Ordering::Relaxed);
        }
        Some(result)
    }

    /// Respawn `shard` if it is still at `observed_generation` — the
    /// generation the caller saw fail.  Concurrent observers of the same
    /// failure all call this; the generation guard makes exactly one of
    /// them rebuild the lane (fresh bounded queue, fresh worker on the
    /// SAME engine `Arc` and seed schedule) while the rest stand down.
    ///
    /// A dead worker is joined; a wedged one is detached — its queue
    /// sender is gone, so it exits on its own when it next touches the
    /// channel, and the purity contract makes any late reply it manages
    /// to deliver bit-identical (and deduplicated) anyway.
    fn heal_shard(&self, shard: usize, observed_generation: u64) {
        let (old_handle, new_generation) = {
            let mut lane = self.lanes[shard].lock().unwrap_or_else(|e| e.into_inner());
            if lane.generation != observed_generation {
                return; // another observer already healed this failure
            }
            lane.generation += 1;
            let (tx, rx) = mpsc::sync_channel::<ShardJob>(SHARD_QUEUE_DEPTH);
            lane.tx = tx; // dropping the old sender retires a live worker
            let old = lane.handle.take();
            lane.handle = Some(spawn_shard_worker(
                shard,
                lane.generation,
                self.engines[shard].clone(),
                rx,
            ));
            (old, lane.generation)
        };
        if let Some(h) = old_handle {
            if h.is_finished() {
                let _ = h.join();
            }
            // else: stalled-but-alive — detach rather than block recovery
            // on a thread the watchdog already gave up on.
        }
        // Pace crash-loops: consecutive heals of the same shard back off
        // exponentially with deterministic jitter; any accepted dispatch
        // resets the streak.  The sleep is outside the lane lock, so the
        // fresh worker (and every other shard) serves while we pause.
        let streak = self.heal_streaks[shard].fetch_add(1, Ordering::Relaxed);
        let pause = heal_backoff(streak, shard);
        if trace::armed() {
            trace::emit(
                EventId::ShardRestart,
                shard as u64,
                new_generation,
                pause.as_millis() as u64,
            );
        }
        std::thread::sleep(pause);
        self.metrics.record_shard_restart();
    }

    /// Deterministically retire and respawn one shard worker,
    /// synchronously: the old worker drains its queue and exits (its
    /// sender is dropped), is joined, and a fresh generation takes over.
    /// In-flight requests are unaffected — they hold their own clone of
    /// the old sender and the old worker answers them before exiting.
    /// This is the test/chaos entry point for exercising the same respawn
    /// path the panic and watchdog recoveries use.
    pub fn kill_shard(&self, shard: usize) {
        let (old_handle, new_generation) = {
            let mut lane = self.lanes[shard].lock().unwrap_or_else(|e| e.into_inner());
            lane.generation += 1;
            let (tx, rx) = mpsc::sync_channel::<ShardJob>(SHARD_QUEUE_DEPTH);
            lane.tx = tx; // the old sender drops: the old worker drains + exits
            let old = lane.handle.take();
            lane.handle = Some(spawn_shard_worker(
                shard,
                lane.generation,
                self.engines[shard].clone(),
                rx,
            ));
            (old, lane.generation)
        };
        if let Some(h) = old_handle {
            let _ = h.join();
        }
        // A deliberate restart is not a crash-loop: no backoff, streak
        // untouched (the next real failure starts from where it was).
        if trace::armed() {
            trace::emit(EventId::ShardRestart, shard as u64, new_generation, 0);
        }
        self.metrics.record_shard_restart();
    }

    /// Enqueue one job on `shard`, healing through dead lanes, and return
    /// the generation that accepted it.  A full queue is backpressure:
    /// the caller polls (bounded by the watchdog) instead of blocking,
    /// because a blocking send into a wedged shard could never recover.
    fn dispatch(&self, shard: usize, mut job: ShardJob) -> Result<u64, ServeError> {
        let slot = job.slot;
        let mut deadline = Instant::now() + self.watchdog;
        let mut heals = 0u32;
        loop {
            let (tx, generation) = {
                let lane = self.lanes[shard].lock().unwrap_or_else(|e| e.into_inner());
                (lane.tx.clone(), lane.generation)
            };
            match tx.try_send(job) {
                Ok(()) => {
                    self.heal_streaks[shard].store(0, Ordering::Relaxed);
                    if trace::armed() {
                        trace::emit(EventId::ShardEnqueue, shard as u64, slot as u64, generation);
                    }
                    return Ok(generation);
                }
                Err(TrySendError::Disconnected(j)) => {
                    // worker died with the queue open: respawn and retry
                    job = j;
                    self.heal_shard(shard, generation);
                    heals += 1;
                }
                Err(TrySendError::Full(j)) => {
                    job = j;
                    if Instant::now() >= deadline {
                        // a full queue for a whole watchdog period is a
                        // wedged worker, not backpressure
                        self.heal_shard(shard, generation);
                        heals += 1;
                        deadline = Instant::now() + self.watchdog;
                    } else {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
            }
            if heals > MAX_SLOT_RETRIES {
                return Err(ServeError::internal(format!(
                    "shard {shard} unavailable after {heals} restarts"
                )));
            }
        }
    }

    /// Evaluate a set of requests across the cluster: memo probe, hash
    /// route, per-shard evaluation, reassembly in request order.  Logits
    /// and logical op counts are bit-identical for every shard count and
    /// every cache/memo state; memo hits additionally book their whole
    /// evaluation into the `*_avoided` counters.
    ///
    /// With the memo enabled, bit-identical requests inside ONE call are
    /// also single-flighted: the first occurrence is dispatched, the
    /// duplicates replay its response (sound for exactly the reason memo
    /// hits are — the answer is a pure function of `(input, method)`),
    /// booked as logical-but-avoided work like any other replay.
    pub fn evaluate(
        &self,
        inputs: &[Vec<f32>],
        method: &Method,
    ) -> Result<BatchResult, ServeError> {
        validate_request(self.num_layers, self.input_dim, inputs, method)?;
        let voters = method.voters();
        let stride = voters * self.classes;
        let n = inputs.len();
        let mut logits = LogitBatch::zeros(n, voters, self.classes);
        let mut ops = OpCounter::default();

        let (rtx, rrx) = mpsc::channel::<ShardReply>();
        // representative slot -> duplicate slots awaiting its reply
        let mut dup_slots: HashMap<usize, Vec<usize>> = HashMap::new();
        // memo key -> representative slots (collisions verified by bits)
        let mut reps_by_key: HashMap<u64, Vec<usize>> = HashMap::new();
        // representative slot -> (shard, accepted generation, resubmits)
        let mut pending: HashMap<usize, PendingSlot> = HashMap::new();
        for (slot, x) in inputs.iter().enumerate() {
            if let Some(hit) = self.memo.as_ref().and_then(|m| m.lookup(method, x)) {
                if trace::armed() {
                    trace::emit(EventId::MemoReplay, slot as u64, 0, 0);
                }
                logits.data_mut()[slot * stride..(slot + 1) * stride].copy_from_slice(&hit.flat);
                ops += replay_ops(hit.muls, hit.adds);
                continue;
            }
            let key = request_key(method, x);
            if self.memo.is_some() {
                // single-flight within the call: only the memo makes
                // replays observable policy, so dedup rides its switch
                let reps = reps_by_key.entry(key).or_default();
                let dup_of = reps.iter().copied().find(|&r| slices_bit_equal(&inputs[r], x));
                if let Some(rep) = dup_of {
                    dup_slots.get_mut(&rep).expect("group exists").push(slot);
                    continue;
                }
                reps.push(slot);
            }
            dup_slots.insert(slot, Vec::new());
            let shard = (key % self.lanes.len() as u64) as usize;
            let job =
                ShardJob { slot, input: x.clone(), method: method.clone(), respond: rtx.clone() };
            let generation = self.dispatch(shard, job)?;
            // resubmissions after a failure are recovery, not traffic:
            // only first dispatches count toward shard attribution, so
            // the breakdown (and snapshot dirty marker) stay independent
            // of how many faults were ridden out along the way
            self.dispatched[shard].fetch_add(1, Ordering::Relaxed);
            pending.insert(slot, PendingSlot { shard, generation, attempts: 0 });
        }

        // Reassemble under supervision.  Every pending slot either fills
        // its logits bit-exactly — possibly after a heal + resubmit — or
        // the whole call fails with a typed error once a slot exhausts
        // [`MAX_SLOT_RETRIES`].  `rtx` stays alive until the loop exits,
        // so `recv_timeout` can only yield replies or a true timeout.
        while !pending.is_empty() {
            let reply = match rrx.recv_timeout(self.watchdog) {
                Ok(r) => r,
                Err(RecvTimeoutError::Timeout) => {
                    // Nothing answered for a whole watchdog period with
                    // work outstanding: presume the involved shards are
                    // wedged, respawn them, resubmit what is pending.  A
                    // merely-slow shard's late answer remains harmless —
                    // bit-identical by purity and dropped as a duplicate.
                    let slots: Vec<usize> = pending.keys().copied().collect();
                    for slot in slots {
                        self.resubmit(slot, &inputs[slot], method, &rtx, &mut pending)?;
                    }
                    continue;
                }
                Err(RecvTimeoutError::Disconnected) => return Err(ServeError::ShuttingDown),
            };
            let slot = reply.slot;
            if !pending.contains_key(&slot) {
                continue; // stale duplicate from a detached worker
            }
            match reply.outcome {
                Ok((flat, rops)) => {
                    pending.remove(&slot);
                    logits.data_mut()[slot * stride..(slot + 1) * stride].copy_from_slice(&flat);
                    ops += rops;
                    for &dup in &dup_slots[&slot] {
                        logits.data_mut()[dup * stride..(dup + 1) * stride]
                            .copy_from_slice(&flat);
                        ops += replay_ops(rops.muls, rops.adds);
                    }
                    if let Some(m) = &self.memo {
                        m.insert(
                            method,
                            &inputs[slot],
                            MemoResponse {
                                flat,
                                voters,
                                classes: self.classes,
                                muls: rops.muls,
                                adds: rops.adds,
                            },
                        );
                    }
                }
                Err(()) => {
                    // the worker caught its own panic, answered, and
                    // retired; respawn the shard and run the slot again
                    self.metrics.record_panic_caught();
                    self.resubmit(slot, &inputs[slot], method, &rtx, &mut pending)?;
                }
            }
        }
        Ok(BatchResult { logits, ops })
    }

    /// Heal the shard a failed slot was dispatched to, then dispatch the
    /// slot again, debiting its retry budget.  Shared by the panic-reply
    /// and watchdog-timeout recovery paths.
    fn resubmit(
        &self,
        slot: usize,
        input: &[f32],
        method: &Method,
        rtx: &mpsc::Sender<ShardReply>,
        pending: &mut HashMap<usize, PendingSlot>,
    ) -> Result<(), ServeError> {
        let PendingSlot { shard, generation, attempts } =
            *pending.get(&slot).expect("slot is pending");
        self.heal_shard(shard, generation);
        if attempts >= MAX_SLOT_RETRIES {
            return Err(ServeError::internal(format!(
                "request slot {slot} failed {attempts} resubmissions on shard {shard}"
            )));
        }
        let job = ShardJob {
            slot,
            input: input.to_vec(),
            method: method.clone(),
            respond: rtx.clone(),
        };
        let accepted = self.dispatch(shard, job)?;
        pending.insert(slot, PendingSlot { shard, generation: accepted, attempts: attempts + 1 });
        Ok(())
    }

    /// Predicted class per input (mean-logit vote + argmax), mirroring
    /// `Engine::predict_batch`.
    pub fn predict_batch(&self, inputs: &[Vec<f32>], method: &Method) -> Vec<usize> {
        self.evaluate(inputs, method)
            .expect("cluster predict: request validation failed")
            .logits
            .iter()
            .map(|stack| vote::argmax(&vote::mean_vote_flat(stack.flat(), stack.classes())))
            .collect()
    }

    /// Batched test-set accuracy over a flat row-major image buffer,
    /// mirroring `Engine::accuracy` (same shared driver).
    pub fn accuracy(&self, images: &[f32], labels: &[u8], method: &Method, batch: usize) -> f64 {
        accuracy_over(images, labels, self.input_dim, batch, |xs| {
            self.predict_batch(xs, method)
        })
    }

    /// Per-shard serving + cache-attribution breakdown.
    pub fn shard_breakdown(&self) -> Vec<ShardBreakdown> {
        let attr = self.service.as_ref().map(|s| s.per_engine());
        (0..self.engines.len())
            .map(|i| ShardBreakdown {
                shard: i,
                requests: self.dispatched[i].load(Ordering::Relaxed),
                cache: attr.as_ref().map(|a| a[i]).unwrap_or_default(),
            })
            .collect()
    }

    /// Serving metrics with the shared-cache aggregate, the memo counters
    /// and the per-shard breakdown folded in — the cluster analogue of
    /// `Engine::metrics_summary`.
    pub fn metrics_summary(&self) -> MetricsSummary {
        let mut s = self.metrics.summary();
        s.cache = self.service.as_ref().map(|svc| svc.stats());
        s.memo = self.memo.as_ref().map(|m| m.stats());
        s.shards = self.shard_breakdown();
        // Shard engines share the process-wide sparse-dispatch counters,
        // so any one engine reports the deployment-wide view.
        s.sparsity = self.engines.first().and_then(|e| e.sparsity_stats());
        s
    }
}

impl InferenceBackend for ClusterRouter {
    fn run_batch(
        &self,
        inputs: &[Vec<f32>],
        method: &InferenceMethod,
    ) -> Result<LogitBatch, ServeError> {
        self.evaluate(inputs, &method.to_reference()).map(|r| r.logits)
    }
}

/// Op bookkeeping for a replayed response (memo hit or intra-batch
/// duplicate): logical counts advance exactly as if the work had run,
/// and all of it is marked avoided.
fn replay_ops(muls: u64, adds: u64) -> OpCounter {
    OpCounter { muls, adds, muls_avoided: muls, adds_avoided: adds }
}

/// Backoff before the `streak`-th consecutive respawn of one shard: the
/// client-side [`RetryPolicy`] curve (capped doubling, deterministic
/// jitter, no entropy source) from a 1 ms base, exponent clamped so the
/// worst crash-loop pause stays under ~80 ms — far below the watchdog,
/// so recovery paces itself without ever looking like a stall.
fn heal_backoff(streak: u32, shard: usize) -> Duration {
    RetryPolicy { max: 0, base_ms: 1 }.delay(streak.min(6), 0x5A1D ^ shard as u64)
}

impl Drop for ClusterRouter {
    fn drop(&mut self) {
        // persist first (workers are still parked, cache is quiescent
        // once the queues close) unless an explicit save already captured
        // the final traffic; then close the queues and reap the shards
        if self.saved_version.load(Ordering::Relaxed) != self.traffic_version() {
            if let Some(Err(e)) = self.save_snapshot() {
                eprintln!("cluster: cache snapshot save failed: {e}");
            }
        }
        let mut handles = Vec::with_capacity(self.lanes.len());
        for lane in &self.lanes {
            let mut lane = lane.lock().unwrap_or_else(|e| e.into_inner());
            // swap in a pre-disconnected sender: dropping the real one
            // ends the current worker's recv loop
            let (tx, rx) = mpsc::sync_channel::<ShardJob>(1);
            drop(rx);
            lane.tx = tx;
            if let Some(h) = lane.handle.take() {
                handles.push(h);
            }
        }
        for h in handles {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for ClusterRouter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ClusterRouter")
            .field("shards", &self.engines.len())
            .field("memo", &self.memo.as_ref().map(|m| m.stats()))
            .field("cache", &self.service.as_ref().map(|s| s.stats()))
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grng::uniform::{UniformSource, XorShift128Plus};

    const ARCH: [usize; 4] = [16, 12, 8, 5];

    fn cfg() -> EngineConfig {
        EngineConfig {
            workers: 2,
            seed: 0xC1A5,
            cache: CacheConfig::disabled(),
            seed_schedule: SeedSchedule::ContentHash,
            alpha: 1.0,
            shards: 1,
            memo: MemoConfig::disabled(),
            snapshot: None,
            sparse_threshold: None,
        }
    }

    fn router(shards: usize) -> ClusterRouter {
        ClusterRouter::new(BnnModel::synthetic(&ARCH, 11), EngineConfig { shards, ..cfg() })
    }

    fn inputs(count: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut r = XorShift128Plus::new(seed);
        (0..count).map(|_| (0..ARCH[0]).map(|_| r.next_f32()).collect()).collect()
    }

    #[test]
    fn routes_and_reassembles_in_request_order() {
        let r = router(3);
        assert_eq!(r.shards(), 3);
        let xs = inputs(9, 1);
        let m = Method::Standard { t: 3 };
        let got = r.evaluate(&xs, &m).expect("evaluate");
        assert_eq!(got.logits.len(), 9);
        // every request matches its own single-request evaluation
        let solo = router(1);
        for (i, x) in xs.iter().enumerate() {
            let one = solo.evaluate(std::slice::from_ref(x), &m).unwrap();
            assert_eq!(got.logits.input(i).flat(), one.logits.input(0).flat(), "slot {i}");
        }
        let total: u64 = r.shard_breakdown().iter().map(|b| b.requests).sum();
        assert_eq!(total, 9, "every request attributed to a shard");
    }

    #[test]
    fn rejects_malformed_requests_like_the_engine_backend() {
        let r = router(2);
        let m = Method::Standard { t: 2 };
        let err = r.evaluate(&[vec![0.0; 3]], &m).unwrap_err();
        assert!(matches!(err, ServeError::DimMismatch(_)), "{err:?}");
        assert!(err.to_string().contains("dim"), "{err}");
        let err = r.evaluate(&inputs(1, 2), &Method::DmBnn { schedule: vec![2, 2] }).unwrap_err();
        assert!(err.to_string().contains("layers"), "{err}");
        let err = r.evaluate(&inputs(1, 2), &Method::Standard { t: 0 }).unwrap_err();
        assert!(err.to_string().contains("zero voters"), "{err}");
    }

    #[test]
    fn memo_skips_the_voter_sweep_on_exact_repeats() {
        let r = ClusterRouter::new(
            BnnModel::synthetic(&ARCH, 11),
            EngineConfig { shards: 2, memo: MemoConfig::with_mb(4), ..cfg() },
        );
        let xs = inputs(4, 3);
        let m = Method::DmBnn { schedule: vec![2, 2, 1] };
        let cold = r.evaluate(&xs, &m).unwrap();
        assert_eq!(cold.ops.muls_avoided, 0, "cold run computes everything");
        let warm = r.evaluate(&xs, &m).unwrap();
        assert_eq!(warm.logits, cold.logits, "memo must replay bit-exactly");
        assert_eq!(warm.ops.muls, cold.ops.muls, "logical counts invariant");
        assert_eq!(warm.ops.adds, cold.ops.adds);
        assert_eq!(warm.ops.performed_muls(), 0, "warm run avoids every mul");
        assert_eq!(warm.ops.performed_adds(), 0);
        let stats = r.metrics_summary().memo.expect("memo enabled");
        assert_eq!(stats.hits, 4);
        assert_eq!(stats.misses, 4);
        assert_eq!(stats.muls_avoided, warm.ops.muls_avoided);
    }

    #[test]
    fn intra_batch_duplicates_single_flight_with_memo() {
        let memo_on = ClusterRouter::new(
            BnnModel::synthetic(&ARCH, 11),
            EngineConfig { shards: 2, memo: MemoConfig::with_mb(4), ..cfg() },
        );
        let base = inputs(2, 9);
        let xs: Vec<Vec<f32>> = (0..8).map(|i| base[i % 2].clone()).collect();
        let m = Method::Standard { t: 3 };
        let got = memo_on.evaluate(&xs, &m).unwrap();
        // reference: the two unique requests, computed without any memo
        let reference = router(1).evaluate(&base, &m).unwrap();
        for (i, x) in xs.iter().enumerate() {
            let j = base.iter().position(|b| b == x).unwrap();
            assert_eq!(got.logits.input(i).flat(), reference.logits.input(j).flat(), "slot {i}");
        }
        // logical counts advance per request (4 copies of each unique)...
        assert_eq!(got.ops.muls, 4 * reference.ops.muls);
        assert_eq!(got.ops.adds, 4 * reference.ops.adds);
        // ...but only the two representatives were actually computed
        assert_eq!(got.ops.performed_muls(), reference.ops.muls);
        assert_eq!(got.ops.performed_adds(), reference.ops.adds);
        let total: u64 = memo_on.shard_breakdown().iter().map(|b| b.requests).sum();
        assert_eq!(total, 2, "duplicates must not dispatch");
        // without the memo, dedup is off: every slot is computed
        let memo_off = router(2);
        let plain = memo_off.evaluate(&xs, &m).unwrap();
        assert_eq!(plain.logits, got.logits);
        assert_eq!(plain.ops.muls, got.ops.muls);
        assert_eq!(plain.ops.muls_avoided, 0);
        let total: u64 = memo_off.shard_breakdown().iter().map(|b| b.requests).sum();
        assert_eq!(total, 8);
    }

    #[test]
    fn shared_cache_attribution_lands_in_the_summary() {
        let r = ClusterRouter::new(
            BnnModel::synthetic(&ARCH, 11),
            EngineConfig { shards: 2, cache: CacheConfig::with_mb(8), ..cfg() },
        );
        let xs = inputs(6, 5);
        let m = Method::DmBnn { schedule: vec![2, 2, 1] };
        let _ = r.evaluate(&xs, &m).unwrap();
        let _ = r.evaluate(&xs, &m).unwrap(); // repeats hit layer-0 entries
        let s = r.metrics_summary();
        let cache = s.cache.expect("shared cache enabled");
        assert!(cache.hits > 0, "{cache}");
        assert_eq!(s.shards.len(), 2);
        let attr_hits: u64 = s.shards.iter().map(|b| b.cache.hits).sum();
        let attr_misses: u64 = s.shards.iter().map(|b| b.cache.misses).sum();
        assert_eq!(attr_hits, cache.hits, "attribution partitions the aggregate");
        assert_eq!(attr_misses, cache.misses);
    }

    #[test]
    fn empty_batch_is_empty() {
        let r = router(2);
        let got = r.evaluate(&[], &Method::Standard { t: 2 }).unwrap();
        assert!(got.logits.is_empty());
        assert_eq!(got.ops, OpCounter::default());
    }

    #[test]
    fn kill_shard_respawns_on_the_same_seed_schedule() {
        let r = router(3);
        let xs = inputs(6, 17);
        let m = Method::Standard { t: 3 };
        let before = r.evaluate(&xs, &m).expect("pre-restart evaluate");
        for shard in 0..3 {
            r.kill_shard(shard);
        }
        let after = r.evaluate(&xs, &m).expect("post-restart evaluate");
        // respawned workers share the engines (and ContentHash schedule),
        // so a full cluster restart is invisible in the results
        assert_eq!(before.logits, after.logits);
        assert_eq!(before.ops.muls, after.ops.muls);
        assert_eq!(before.ops.adds, after.ops.adds);
        let s = r.metrics_summary();
        if fault::armed() {
            // a chaos run may ride out extra panics/restarts on the side
            assert!(s.shard_restarts >= 3, "{}", s.shard_restarts);
        } else {
            assert_eq!(s.shard_restarts, 3);
            assert_eq!(s.panics_caught, 0, "a clean restart catches nothing");
        }
    }

    #[test]
    fn repeated_restarts_of_one_shard_keep_serving() {
        let r = router(2);
        let xs = inputs(4, 23);
        let m = Method::Standard { t: 2 };
        let reference = r.evaluate(&xs, &m).unwrap();
        for _ in 0..5 {
            r.kill_shard(0);
            let again = r.evaluate(&xs, &m).unwrap();
            assert_eq!(again.logits, reference.logits);
        }
        if !fault::armed() {
            assert_eq!(r.metrics_summary().shard_restarts, 5);
        }
    }

    #[test]
    fn heal_backoff_is_deterministic_and_bounded() {
        for shard in 0..4usize {
            for streak in 0..20u32 {
                let d = heal_backoff(streak, shard);
                assert_eq!(d, heal_backoff(streak, shard), "backoff must replay");
                assert!(d >= Duration::from_millis(1), "streak {streak}: {d:?}");
                // 1 ms base, exponent clamped at 6, +25% jitter: < 80 ms
                assert!(d <= Duration::from_millis(80), "streak {streak}: {d:?}");
            }
        }
        // the streak actually escalates the pause
        assert!(heal_backoff(6, 0) > heal_backoff(0, 0));
        // distinct shards draw distinct jitter at the same streak
        assert!((0..64).any(|s| heal_backoff(5, s) != heal_backoff(5, 0)));
    }

    #[test]
    fn watchdog_env_parses_defensively() {
        // unset in the default test environment ⇒ the 30 s default; chaos
        // tests shrink it via BAYESDM_WATCHDOG_MS
        assert!(watchdog_from_env() >= Duration::from_millis(1));
    }

    #[test]
    fn router_is_send_and_sync() {
        // the generic server shares one backend across worker threads
        fn assert_sync<T: Send + Sync>() {}
        assert_sync::<ClusterRouter>();
    }

    #[test]
    fn env_shards_default_parses_defensively() {
        // unset in the default environment of this test run ⇒ 1; the CI
        // cluster leg sets it and tests/cluster_parity.rs covers that path
        assert!(shards_from_env() >= 1);
    }
}
