//! Cache warm-up / persistence: serialize hot decomposition-cache entries
//! at shutdown, reload them at start, so a restarted deployment serves
//! its first repeated request warm instead of re-running the μ-path
//! GEMVs.
//!
//! # Format (version 1, little-endian throughout)
//!
//! ```text
//! magic    8 bytes   b"BDMSNAP\x01"
//! version  u32       SNAPSHOT_VERSION
//! fp       u64       model fingerprint the entries belong to
//! count    u64       number of entries
//! checksum u64       mix64(fnv1a(payload bytes))
//! payload  per entry: layer u32, x_len u32, m u32,
//!          then x (x_len f32 bits), eta (m f32 bits),
//!          beta (m·x_len f32 bits)
//! ```
//!
//! `beta`'s length is derived (`m × x_len`), so a corrupt length field
//! cannot desynchronize silently — every read is bounds-checked against
//! the checksummed payload.
//!
//! # Safety argument: stale snapshots degrade, never lie
//!
//! Three independent gates keep a snapshot from producing wrong results:
//!
//! 1. **Header fingerprint** — a snapshot written for another model (or
//!    another version of this format) is rejected wholesale at load; the
//!    deployment starts cold, exactly as if the file did not exist.
//! 2. **Checksum** — torn/corrupt files are rejected wholesale.
//! 3. **Stored-key bit-verification** — loaded entries re-enter the cache
//!    through `DmCache::insert`, which stores the full key (fingerprint,
//!    layer, input bits); every subsequent `lookup` bit-compares the
//!    stored key, so even an adversarially crafted payload can at worst
//!    produce misses or wrong-valued *entries that never verify*, not
//!    wrong responses.
//!
//! Loading therefore never errors a deployment: every failure mode is
//! reported via [`SnapshotReport::rejected`] and serving proceeds cold.
//! Only *writing* can hard-fail (disk errors on save).

use std::path::Path;
use std::sync::Arc;

use crate::nn::dmcache::{Decomp, DmCache};
use crate::serve::ServeError;
use crate::util::hash::{fnv1a_bytes, mix64, FNV_OFFSET};

/// Snapshot file magic (8 bytes).
pub const SNAPSHOT_MAGIC: [u8; 8] = *b"BDMSNAP\x01";

/// Bumped whenever the entry layout changes; old files degrade to cold.
pub const SNAPSHOT_VERSION: u32 = 1;

const HEADER_BYTES: usize = 8 + 4 + 8 + 8 + 8;

/// Outcome of a snapshot save or load.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SnapshotReport {
    /// Entries written (save) or handed to the cache (load — the cache's
    /// own budget may still decline or evict some).
    pub entries: usize,
    /// Payload bytes written/read.
    pub payload_bytes: usize,
    /// Why the snapshot was rejected and the deployment started cold
    /// (load only); `None` on success.
    pub rejected: Option<String>,
}

impl std::fmt::Display for SnapshotReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.rejected {
            Some(why) => write!(f, "cold start ({why})"),
            None => write!(f, "entries={} payload_bytes={}", self.entries, self.payload_bytes),
        }
    }
}

fn push_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    for &x in xs {
        buf.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

/// Serialize every live entry of model `fp` to `path` (written to a
/// `.tmp` sibling first, then renamed, so a crash mid-save cannot leave a
/// torn file where the next start expects a snapshot).
pub fn save(cache: &DmCache, fp: u64, path: &Path) -> Result<SnapshotReport, ServeError> {
    let entries = cache.export_for(fp);
    let mut payload = Vec::new();
    for e in &entries {
        let m = e.decomp.eta.len();
        payload.extend_from_slice(&e.layer.to_le_bytes());
        payload.extend_from_slice(&(e.x.len() as u32).to_le_bytes());
        payload.extend_from_slice(&(m as u32).to_le_bytes());
        push_f32s(&mut payload, &e.x);
        push_f32s(&mut payload, &e.decomp.eta);
        push_f32s(&mut payload, &e.decomp.beta);
    }
    let mut file = Vec::with_capacity(HEADER_BYTES + payload.len());
    file.extend_from_slice(&SNAPSHOT_MAGIC);
    file.extend_from_slice(&SNAPSHOT_VERSION.to_le_bytes());
    file.extend_from_slice(&fp.to_le_bytes());
    file.extend_from_slice(&(entries.len() as u64).to_le_bytes());
    file.extend_from_slice(&mix64(fnv1a_bytes(FNV_OFFSET, &payload)).to_le_bytes());
    file.extend_from_slice(&payload);

    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, &file)
        .map_err(|e| ServeError::internal(format!("write {}: {e}", tmp.display())))?;
    if crate::util::fault::should_fire("snapshot.save") {
        // Simulated write failure after the `.tmp` landed but before the
        // rename: clean the sibling up and fail — an existing snapshot at
        // `path` must be untouched (the atomicity the chaos suite pins).
        let _ = std::fs::remove_file(&tmp);
        return Err(ServeError::internal(format!(
            "fault injected: snapshot.save ({})",
            tmp.display()
        )));
    }
    std::fs::rename(&tmp, path).map_err(|e| {
        ServeError::internal(format!("rename {} -> {}: {e}", tmp.display(), path.display()))
    })?;
    Ok(SnapshotReport { entries: entries.len(), payload_bytes: payload.len(), rejected: None })
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        let s = self.buf.get(self.pos..end)?;
        self.pos = end;
        Some(s)
    }

    fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|b| u32::from_le_bytes(b.try_into().unwrap()))
    }

    fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|b| u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn f32s(&mut self, n: usize) -> Option<Vec<f32>> {
        let raw = self.take(n.checked_mul(4)?)?;
        let mut out = Vec::with_capacity(n);
        for c in raw.chunks_exact(4) {
            out.push(f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())));
        }
        Some(out)
    }
}

fn cold(why: impl Into<String>) -> SnapshotReport {
    SnapshotReport { entries: 0, payload_bytes: 0, rejected: Some(why.into()) }
}

/// Load a snapshot into `cache`, gated on model fingerprint `fp`.  Never
/// fails the deployment: a missing, stale, corrupt or truncated snapshot
/// returns a report with [`SnapshotReport::rejected`] set and the cache
/// untouched (cold start).
pub fn load(cache: &DmCache, fp: u64, path: &Path) -> SnapshotReport {
    if crate::util::fault::should_fire("snapshot.corrupt") {
        // exercise the cold-start degradation without real disk damage
        return cold("fault injected: snapshot.corrupt");
    }
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) => return cold(format!("unreadable snapshot {}: {e}", path.display())),
    };
    if bytes.len() < HEADER_BYTES {
        return cold("truncated header");
    }
    let mut r = Reader { buf: &bytes, pos: 0 };
    if r.take(8) != Some(&SNAPSHOT_MAGIC) {
        return cold("bad magic");
    }
    let version = r.u32().unwrap();
    if version != SNAPSHOT_VERSION {
        return cold(format!("version {version} != {SNAPSHOT_VERSION}"));
    }
    let file_fp = r.u64().unwrap();
    if file_fp != fp {
        return cold(format!("model fingerprint mismatch ({file_fp:#x} != {fp:#x})"));
    }
    let count = r.u64().unwrap();
    let checksum = r.u64().unwrap();
    let payload = &bytes[HEADER_BYTES..];
    if mix64(fnv1a_bytes(FNV_OFFSET, payload)) != checksum {
        return cold("payload checksum mismatch");
    }

    // Parse fully before touching the cache: a snapshot is all-or-nothing.
    let mut parsed = Vec::new();
    for i in 0..count {
        let (layer, x, decomp) = match parse_entry(&mut r) {
            Some(e) => e,
            None => return cold(format!("truncated entry {i}/{count}")),
        };
        parsed.push((layer, x, decomp));
    }
    if r.pos != bytes.len() {
        return cold("trailing bytes after last entry");
    }

    let payload_bytes = payload.len();
    let entries = parsed.len();
    for (layer, x, decomp) in parsed {
        cache.insert(fp, layer as usize, &x, &decomp);
    }
    SnapshotReport { entries, payload_bytes, rejected: None }
}

fn parse_entry(r: &mut Reader<'_>) -> Option<(u32, Vec<f32>, Arc<Decomp>)> {
    let layer = r.u32()?;
    let x_len = r.u32()? as usize;
    let m = r.u32()? as usize;
    let x = r.f32s(x_len)?;
    let eta = r.f32s(m)?;
    let beta = r.f32s(m.checked_mul(x_len)?)?;
    Some((layer, x, Arc::new(Decomp { beta, eta })))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::dmcache::CacheConfig;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("bayesdm_snapshot_{}_{name}.bin", std::process::id()))
    }

    fn warm_cache(fp: u64) -> DmCache {
        let c = DmCache::new(&CacheConfig::with_mb(2));
        for i in 0..5u32 {
            let x: Vec<f32> = (0..4).map(|j| (i * 4 + j) as f32).collect();
            let m = 3usize;
            let decomp = Arc::new(Decomp {
                beta: (0..m * 4).map(|k| k as f32 * 0.5).collect(),
                eta: (0..m).map(|k| k as f32 - 1.0).collect(),
            });
            c.insert(fp, (i % 2) as usize, &x, &decomp);
        }
        c
    }

    #[test]
    fn save_load_roundtrip_restores_warm_hits() {
        let path = tmp("roundtrip");
        let warm = warm_cache(0xF1);
        let report = save(&warm, 0xF1, &path).expect("save");
        assert_eq!(report.entries, 5);
        assert!(report.rejected.is_none());

        let fresh = DmCache::new(&CacheConfig::with_mb(2));
        let loaded = load(&fresh, 0xF1, &path);
        assert_eq!(loaded.rejected, None, "{loaded}");
        assert_eq!(loaded.entries, 5);
        // every original entry now hits, bit-exactly
        for i in 0..5u32 {
            let x: Vec<f32> = (0..4).map(|j| (i * 4 + j) as f32).collect();
            let layer = (i % 2) as usize;
            let got = fresh.lookup(0xF1, layer, &x).expect("warm hit");
            let want = warm.lookup(0xF1, layer, &x).unwrap();
            assert_eq!(*got, *want, "entry {i}");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn stale_fingerprint_degrades_to_cold() {
        let path = tmp("stale");
        save(&warm_cache(0xA1), 0xA1, &path).expect("save");
        let fresh = DmCache::new(&CacheConfig::with_mb(2));
        let report = load(&fresh, 0xB2, &path);
        assert!(report.rejected.as_deref().unwrap_or("").contains("fingerprint"), "{report:?}");
        assert_eq!(report.entries, 0);
        assert_eq!(fresh.stats().entries, 0, "stale snapshot must not warm the cache");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_corrupt_and_truncated_files_degrade_to_cold() {
        let fresh = DmCache::new(&CacheConfig::with_mb(2));
        let missing = load(&fresh, 1, &tmp("never_written"));
        assert!(missing.rejected.is_some());

        let garbage = tmp("garbage");
        std::fs::write(&garbage, b"definitely not a snapshot").unwrap();
        assert!(load(&fresh, 1, &garbage).rejected.is_some());

        // valid file with one flipped payload byte: checksum rejects it
        let warm = warm_cache(0xC3);
        let path = tmp("bitflip");
        save(&warm, 0xC3, &path).expect("save");
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let report = load(&fresh, 0xC3, &path);
        assert!(report.rejected.as_deref().unwrap_or("").contains("checksum"), "{report:?}");
        assert_eq!(fresh.stats().entries, 0);
        let _ = std::fs::remove_file(&garbage);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn save_filters_to_the_requested_fingerprint() {
        let path = tmp("filter");
        let c = warm_cache(0xD4);
        let other = Arc::new(Decomp { beta: vec![1.0; 4], eta: vec![1.0; 2] });
        c.insert(0xEE, 0, &[9.0, 9.0], &other);
        let report = save(&c, 0xD4, &path).expect("save");
        assert_eq!(report.entries, 5, "other model's entry excluded");
        let _ = std::fs::remove_file(&path);
    }
}
