//! Hardware cost simulator — the substitution for the paper's Verilog +
//! Synopsys DC + 45 nm FreePDK + CACTI toolchain (DESIGN.md §3).
//!
//! The paper evaluates three accelerator organizations (Standard,
//! Hybrid-BNN, DM-BNN) for area (mm²), energy (µJ) and runtime (µs) on
//! one MNIST inference (Table V), plus the area-vs-α sweep of the
//! memory-friendly framework (Fig 7).  Those numbers decompose as
//!
//! ```text
//!   runtime = weighted cycles / (lanes × f_clk)   (+ memory stalls)
//!   energy  = Σ op_count × op_energy  +  Σ sram_accesses × access_energy
//!             + leakage × runtime
//!   area    = PE array + SRAM macros + GRNG bank + control overhead
//! ```
//!
//! [`units`] holds the 45 nm-calibrated unit costs (Horowitz ISSCC'14 for
//! arithmetic, a CACTI-style macro model in [`sram`]); [`arch`] composes
//! them into the three organizations; [`sim`] runs a method's op/access
//! trace through an organization; [`report`] renders Table V and Fig 7.
//!
//! Absolute values are *calibrated estimates* — the claims preserved are
//! the paper's ratios: DM-BNN ≈ −73 % energy, ≈ 4× speedup, ≈ +14 % area
//! at α = 0.1; Hybrid worst in area because its first layer needs a
//! second datapath mechanism; area monotone decreasing with α.

pub mod arch;
pub mod report;
pub mod sim;
pub mod sram;
pub mod units;

pub use arch::{AcceleratorConfig, Organization};
pub use report::{fig7_rows, table5_rows, Fig7Row, Table5Row};
pub use sim::{simulate, HwReport};
