//! Accelerator organizations: the three Table V designs.
//!
//! All three share a MAC lane array (each lane: one 8-bit multiplier, one
//! 8-bit adder, one CLT GRNG), the weight SRAM (μ and σ at 8 bits each)
//! and ping-pong activation buffers for the voters evaluated in parallel.
//! They differ exactly where the paper says they do (§V-B2):
//!
//! * **Standard** — nothing else.  Best area: one mechanism, no extra
//!   memory.
//! * **Hybrid** — layer 1 needs a *different computing mechanism* from the
//!   other layers, so it instantiates a second (DM) datapath next to the
//!   standard one, plus the layer-1 β/η bank.  Worst area.
//! * **DM-BNN** — one DM mechanism shared by all layers (a precompute
//!   sequencer extends the array) plus per-layer β/η banks sized by α.

use crate::layer_dims;

use super::sram::SramBank;
use super::units;

/// Which Table V design.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Organization {
    Standard,
    Hybrid,
    DmBnn,
}

impl Organization {
    pub fn name(&self) -> &'static str {
        match self {
            Organization::Standard => "Standard BNN",
            Organization::Hybrid => "Hybrid-BNN",
            Organization::DmBnn => "DM-BNN",
        }
    }
}

/// A concrete accelerator instance.
#[derive(Debug, Clone)]
pub struct AcceleratorConfig {
    pub org: Organization,
    /// Network architecture, e.g. [784, 200, 200, 10].
    pub arch: Vec<usize>,
    /// Parallel MAC lanes.
    pub lanes: usize,
    /// Memory-friendly blocking factor α ∈ (0, 1] (Fig 5 / Fig 7).
    pub alpha: f64,
    /// Voters evaluated simultaneously (αT in the paper's framing).
    pub voters_parallel: usize,
}

impl AcceleratorConfig {
    /// The paper's Table V design point for a given organization:
    /// 784-200-200-10, α = 0.1, T = 100 ⇒ 10 voters in flight.
    pub fn paper_table5(org: Organization) -> Self {
        Self {
            org,
            arch: crate::MNIST_ARCH.to_vec(),
            lanes: 256,
            alpha: 0.1,
            voters_parallel: 10,
        }
    }

    fn dims(&self) -> Vec<(usize, usize)> {
        layer_dims(&self.arch)
    }

    /// Weight store: μ and σ at 1 byte each, plus biases.
    pub fn weight_sram(&self) -> SramBank {
        let words: usize = self.dims().iter().map(|(m, n)| m * n + m).sum();
        SramBank::new(2 * words as u64)
    }

    /// β/η banks.  Hybrid: layer 1 only.  DM: one bank per layer.  The β
    /// slice held at once is α·M·N (Fig 5); η is α-independent (M words).
    pub fn beta_srams(&self) -> Vec<SramBank> {
        let per_layer = |m: usize, n: usize| {
            let beta = (self.alpha * (m * n) as f64).ceil() as u64;
            SramBank::new(beta + m as u64)
        };
        match self.org {
            Organization::Standard => vec![],
            Organization::Hybrid => {
                let (m, n) = self.dims()[0];
                vec![per_layer(m, n)]
            }
            Organization::DmBnn => {
                self.dims().iter().map(|&(m, n)| per_layer(m, n)).collect()
            }
        }
    }

    /// Activation ping-pong buffers: 2 × voters_parallel × max layer width.
    pub fn activation_sram(&self) -> SramBank {
        let max_m = self.dims().iter().map(|&(m, _)| m).max().unwrap_or(0);
        SramBank::new((2 * self.voters_parallel * max_m) as u64)
    }

    /// MAC lane array area (mm²): multiplier + adder + GRNG per lane.
    pub fn pe_array_area_mm2(&self) -> f64 {
        self.lanes as f64
            * (units::MUL8_AREA_UM2 + units::ADD8_AREA_UM2 + units::GRNG_AREA_UM2)
            / 1e6
    }

    /// Extra datapath area beyond the shared lane array.
    ///
    /// * Hybrid: a full second lane array — the DM mechanism for layer 1
    ///   cannot share hardware with the standard mechanism of layers ≥ 2
    ///   (the paper's stated reason its area is worst).
    /// * DM: a precompute sequencer + writeback path, ~25 % of the array —
    ///   the mechanism is shared across layers, only the front-end grows.
    pub fn datapath_overhead_mm2(&self) -> f64 {
        match self.org {
            Organization::Standard => 0.0,
            Organization::Hybrid => self.pe_array_area_mm2(),
            Organization::DmBnn => 0.25 * self.pe_array_area_mm2(),
        }
    }

    /// Total die area (mm²) including control overhead.
    pub fn area_mm2(&self) -> f64 {
        let core = self.pe_array_area_mm2()
            + self.datapath_overhead_mm2()
            + self.weight_sram().area_mm2()
            + self.activation_sram().area_mm2()
            + self.beta_srams().iter().map(|b| b.area_mm2()).sum::<f64>();
        core * (1.0 + units::CONTROL_AREA_OVERHEAD)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_area_ordering() {
        // Paper Table V: Standard (5.76) < DM (6.63) < Hybrid (7.33).
        let std = AcceleratorConfig::paper_table5(Organization::Standard).area_mm2();
        let hyb = AcceleratorConfig::paper_table5(Organization::Hybrid).area_mm2();
        let dm = AcceleratorConfig::paper_table5(Organization::DmBnn).area_mm2();
        assert!(std < dm, "standard {std} !< dm {dm}");
        assert!(dm < hyb, "dm {dm} !< hybrid {hyb}");
    }

    #[test]
    fn dm_area_overhead_in_paper_band() {
        // Paper: DM +14 %, Hybrid +27 % at α = 0.1.  Our calibration must
        // land in the same regime (a few to a few-tens of percent, with
        // Hybrid strictly worse).
        let std = AcceleratorConfig::paper_table5(Organization::Standard).area_mm2();
        let dm = AcceleratorConfig::paper_table5(Organization::DmBnn).area_mm2();
        let hyb = AcceleratorConfig::paper_table5(Organization::Hybrid).area_mm2();
        let dm_ovh = dm / std - 1.0;
        let hyb_ovh = hyb / std - 1.0;
        assert!(dm_ovh > 0.02 && dm_ovh < 0.30, "dm overhead {dm_ovh}");
        assert!(hyb_ovh > dm_ovh && hyb_ovh < 0.60, "hybrid overhead {hyb_ovh}");
    }

    #[test]
    fn area_monotone_in_alpha() {
        // Fig 7: smaller α ⇒ smaller area.
        let mut prev = f64::INFINITY;
        for alpha in [1.0, 0.5, 0.2, 0.1, 0.05] {
            let mut c = AcceleratorConfig::paper_table5(Organization::DmBnn);
            c.alpha = alpha;
            let a = c.area_mm2();
            assert!(a < prev, "area not monotone at alpha={alpha}: {a} vs {prev}");
            prev = a;
        }
    }

    #[test]
    fn absolute_area_plausible_45nm() {
        // The paper's designs are 5.76–7.33 mm²; our calibrated model
        // should land within ~3× (same order of magnitude).
        let a = AcceleratorConfig::paper_table5(Organization::Standard).area_mm2();
        assert!(a > 1.0 && a < 20.0, "standard area {a} mm2");
    }

    #[test]
    fn beta_banks_per_org() {
        assert_eq!(
            AcceleratorConfig::paper_table5(Organization::Standard).beta_srams().len(),
            0
        );
        assert_eq!(
            AcceleratorConfig::paper_table5(Organization::Hybrid).beta_srams().len(),
            1
        );
        assert_eq!(
            AcceleratorConfig::paper_table5(Organization::DmBnn).beta_srams().len(),
            3
        );
    }

    #[test]
    fn weight_sram_sized_by_network() {
        let c = AcceleratorConfig::paper_table5(Organization::Standard);
        // 2 bytes per (weight + bias) posterior parameter pair
        let words = 784 * 200 + 200 + 200 * 200 + 200 + 200 * 10 + 10;
        assert_eq!(c.weight_sram().bytes, 2 * words as u64);
    }
}
