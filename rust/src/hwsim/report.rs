//! Table V and Fig 7 renderers.

use super::arch::{AcceleratorConfig, Organization};
use super::sim::{simulate, HwReport};

/// One Table V row (accuracy is measured separately by the quantized
/// functional model in `nn::fixed_infer` and passed in by the caller).
#[derive(Debug, Clone)]
pub struct Table5Row {
    pub method: String,
    pub accuracy: Option<f64>,
    pub area_mm2: f64,
    pub energy_uj: f64,
    pub runtime_us: f64,
}

/// Simulate the three paper design points (α = 0.1).
pub fn table5_rows(accuracy: &[Option<f64>; 3]) -> Vec<Table5Row> {
    [Organization::Standard, Organization::Hybrid, Organization::DmBnn]
        .iter()
        .zip(accuracy)
        .map(|(&org, &acc)| {
            let r: HwReport = simulate(&AcceleratorConfig::paper_table5(org), false);
            Table5Row {
                method: org.name().to_string(),
                accuracy: acc,
                area_mm2: r.area_mm2,
                energy_uj: r.energy_uj,
                runtime_us: r.runtime_us,
            }
        })
        .collect()
}

/// Render Table V with relative columns (the paper's claims are ratios).
pub fn render_table5(rows: &[Table5Row]) -> String {
    let mut s = String::new();
    s.push_str("Table V — hardware implementation results (45 nm model, α = 0.1)\n");
    s.push_str(&format!(
        "  {:<14} {:>9} {:>11} {:>12} {:>12} {:>9} {:>9}\n",
        "Method", "Accuracy", "Area (mm²)", "Energy (µJ)", "Runtime (µs)", "E-red.", "Speedup"
    ));
    let base = &rows[0];
    for r in rows {
        let acc = r
            .accuracy
            .map(|a| format!("{:.2}%", 100.0 * a))
            .unwrap_or_else(|| "--".into());
        s.push_str(&format!(
            "  {:<14} {:>9} {:>11.2} {:>12.1} {:>12.1} {:>8.0}% {:>8.2}x\n",
            r.method,
            acc,
            r.area_mm2,
            r.energy_uj,
            r.runtime_us,
            100.0 * (1.0 - r.energy_uj / base.energy_uj),
            base.runtime_us / r.runtime_us,
        ));
    }
    s
}

/// One Fig 7 point: α vs system area.
#[derive(Debug, Clone, Copy)]
pub struct Fig7Row {
    pub alpha: f64,
    pub area_mm2: f64,
}

/// Sweep α for the DM-BNN organization (Fig 7).
pub fn fig7_rows(alphas: &[f64]) -> Vec<Fig7Row> {
    alphas
        .iter()
        .map(|&alpha| {
            let mut cfg = AcceleratorConfig::paper_table5(Organization::DmBnn);
            cfg.alpha = alpha;
            Fig7Row { alpha, area_mm2: cfg.area_mm2() }
        })
        .collect()
}

/// Render Fig 7 as an ASCII series (value column + bar).
pub fn render_fig7(rows: &[Fig7Row]) -> String {
    let mut s = String::new();
    s.push_str("Fig 7 — system area vs α (DM-BNN organization)\n");
    let max = rows.iter().map(|r| r.area_mm2).fold(0.0f64, f64::max);
    for r in rows {
        let bar = "#".repeat(((r.area_mm2 / max) * 40.0).round() as usize);
        s.push_str(&format!("  α={:<5} {:>8.3} mm²  {}\n", r.alpha, r.area_mm2, bar));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_rows_complete() {
        let rows = table5_rows(&[Some(0.9542), Some(0.9542), Some(0.9535)]);
        assert_eq!(rows.len(), 3);
        assert!(rows[2].energy_uj < rows[0].energy_uj);
        let txt = render_table5(&rows);
        assert!(txt.contains("DM-BNN"));
        assert!(txt.contains("95.42%"));
    }

    #[test]
    fn fig7_monotone_series() {
        let rows = fig7_rows(&[1.0, 0.5, 0.2, 0.1, 0.05]);
        for w in rows.windows(2) {
            assert!(w[1].area_mm2 < w[0].area_mm2);
        }
        let txt = render_fig7(&rows);
        assert!(txt.contains("α=1"));
    }
}
