//! Cycle/energy simulation of one BNN inference on an accelerator config.
//!
//! Builds the per-layer op and memory-traffic trace for a method (the
//! same accounting validated against the instrumented dataflows in
//! `opcount`), then folds it through the unit costs:
//!
//! * cycles: weighted (2×MUL + ADD) compute cycles spread over the lanes,
//!   plus the serialized precompute phases (the DM precompute of layer
//!   ℓ+1 cannot start before a layer-ℓ voter output exists).
//! * energy: arithmetic + SRAM traffic (+ optional GRNG, excluded by
//!   default exactly as the paper excludes it "for fairness"), plus
//!   leakage × runtime.

use crate::layer_dims;
use crate::opcount::model::{CostModel, Method};

use super::arch::{AcceleratorConfig, Organization};
use super::units;

/// Memory traffic trace (bytes, 8-bit words).
#[derive(Debug, Default, Clone, Copy)]
pub struct Traffic {
    pub weight_reads: u64,
    pub beta_reads: u64,
    pub beta_writes: u64,
    pub act_reads: u64,
    pub act_writes: u64,
    pub grng_samples: u64,
}

/// Simulation output for one inference.
#[derive(Debug, Clone)]
pub struct HwReport {
    pub org: Organization,
    pub area_mm2: f64,
    pub energy_uj: f64,
    pub runtime_us: f64,
    pub cycles: u64,
    pub traffic: Traffic,
    pub muls: u64,
    pub adds: u64,
}

/// The inference method the paper maps to each organization.
pub fn method_for(org: Organization) -> Method {
    match org {
        Organization::Standard => Method::Standard { t: 100 },
        Organization::Hybrid => Method::Hybrid { t: 100 },
        Organization::DmBnn => Method::DmBnn { schedule: vec![10, 10, 10] },
    }
}

/// Build the memory-traffic trace for a method on an architecture.
pub fn traffic_for(arch: &[usize], method: &Method) -> Traffic {
    let dims = layer_dims(arch);
    let mut tr = Traffic::default();
    match method {
        Method::Standard { t } => {
            for &(m, n) in &dims {
                let (m, n, t) = (m as u64, n as u64, *t);
                tr.weight_reads += t * 2 * m * n; // σ and μ per voter
                tr.act_reads += t * n;
                tr.act_writes += t * m;
                tr.grng_samples += t * (m * n + m);
            }
        }
        Method::Hybrid { t } => {
            for (li, &(m, n)) in dims.iter().enumerate() {
                let (m, n, t) = (m as u64, n as u64, *t);
                if li == 0 {
                    // precompute once...
                    tr.weight_reads += 2 * m * n;
                    tr.act_reads += n;
                    tr.beta_writes += m * n + m;
                    // ...then T DM voters reading β/η
                    tr.beta_reads += t * (m * n + m);
                    tr.act_writes += t * m;
                } else {
                    tr.weight_reads += t * 2 * m * n;
                    tr.act_reads += t * n;
                    tr.act_writes += t * m;
                }
                tr.grng_samples += t * (m * n + m);
            }
        }
        Method::DmBnn { schedule } => {
            assert_eq!(schedule.len(), dims.len());
            let mut distinct = 1u64;
            for (&(m, n), &tl) in dims.iter().zip(schedule) {
                let (m, n) = (m as u64, n as u64);
                tr.weight_reads += distinct * 2 * m * n;
                tr.act_reads += distinct * n;
                tr.beta_writes += distinct * (m * n + m);
                tr.beta_reads += distinct * tl * (m * n + m);
                tr.act_writes += distinct * tl * m;
                // uncertainty shared across distinct inputs: t_l samples/layer
                tr.grng_samples += tl * (m * n + m);
                distinct *= tl;
            }
        }
    }
    tr
}

/// Run the simulation.  `include_grng_energy = false` reproduces the
/// paper's fairness protocol ("the energy consumption of GRNGs is not
/// calculated").
pub fn simulate(cfg: &AcceleratorConfig, include_grng_energy: bool) -> HwReport {
    let method = method_for(cfg.org);
    let cm = CostModel::from_arch(&cfg.arch);
    let cost = cm.cost(&method, cfg.alpha);
    let tr = traffic_for(&cfg.arch, &method);

    // --- cycles -----------------------------------------------------------
    let weighted =
        units::MUL_CYCLES * cost.total.muls + units::ADD_CYCLES * cost.total.adds;
    let mut cycles = weighted / cfg.lanes as u64;
    // Precompute serialization: each DM layer's precompute is a pipeline
    // bubble of (its weighted ops / lanes) before its voters can start.
    // Approximate as 5% of the voter compute for DM organizations.
    if cfg.org != Organization::Standard {
        cycles += cycles / 20;
    }
    let runtime_us = cycles as f64 / units::CLOCK_MHZ; // cycles / (MHz) = µs

    // --- energy -----------------------------------------------------------
    let weight_bank = cfg.weight_sram();
    let beta_banks = cfg.beta_srams();
    let beta_read_pj = beta_banks
        .first()
        .map(|b| b.read_energy_pj_per_byte())
        .unwrap_or(0.0);
    let beta_write_pj = beta_banks
        .first()
        .map(|b| b.write_energy_pj_per_byte())
        .unwrap_or(0.0);
    let act_bank = cfg.activation_sram();

    let mut energy_pj = cost.total.muls as f64 * units::MUL8_ENERGY_PJ
        + cost.total.adds as f64 * units::ADD8_ENERGY_PJ
        + tr.weight_reads as f64 * weight_bank.read_energy_pj_per_byte()
        + tr.beta_reads as f64 * beta_read_pj
        + tr.beta_writes as f64 * beta_write_pj
        + tr.act_reads as f64 * act_bank.read_energy_pj_per_byte()
        + tr.act_writes as f64 * act_bank.write_energy_pj_per_byte();
    if include_grng_energy {
        energy_pj += tr.grng_samples as f64 * units::GRNG_SAMPLE_ENERGY_PJ;
    }
    let area = cfg.area_mm2();
    // leakage: mW × µs = nJ ⇒ ×1e3 pJ
    energy_pj += units::LEAKAGE_MW_PER_MM2 * area * runtime_us * 1e3;

    HwReport {
        org: cfg.org,
        area_mm2: area,
        energy_uj: energy_pj / 1e6,
        runtime_us,
        cycles,
        traffic: tr,
        muls: cost.total.muls,
        adds: cost.total.adds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(org: Organization) -> HwReport {
        simulate(&AcceleratorConfig::paper_table5(org), false)
    }

    #[test]
    fn table5_energy_reduction_band() {
        // Paper: Hybrid −29 %, DM −73 % energy vs standard.
        let std = report(Organization::Standard);
        let hyb = report(Organization::Hybrid);
        let dm = report(Organization::DmBnn);
        let hyb_red = 1.0 - hyb.energy_uj / std.energy_uj;
        let dm_red = 1.0 - dm.energy_uj / std.energy_uj;
        assert!(hyb_red > 0.15 && hyb_red < 0.55, "hybrid reduction {hyb_red}");
        assert!(dm_red > 0.60 && dm_red < 0.88, "dm reduction {dm_red}");
        assert!(dm_red > hyb_red);
    }

    #[test]
    fn table5_speedup_band() {
        // Paper: Hybrid 1.5×, DM 4× speedup.
        let std = report(Organization::Standard);
        let hyb = report(Organization::Hybrid);
        let dm = report(Organization::DmBnn);
        let s_h = std.runtime_us / hyb.runtime_us;
        let s_d = std.runtime_us / dm.runtime_us;
        assert!(s_h > 1.2 && s_h < 2.2, "hybrid speedup {s_h}");
        assert!(s_d > 3.0 && s_d < 7.0, "dm speedup {s_d}");
    }

    #[test]
    fn runtime_plausible_microseconds() {
        // Paper reports 97–392 µs; same order of magnitude expected.
        let std = report(Organization::Standard);
        assert!(
            std.runtime_us > 50.0 && std.runtime_us < 5000.0,
            "runtime {} µs",
            std.runtime_us
        );
    }

    #[test]
    fn grng_sampling_counts() {
        // Standard: 100 samples/layer; DM: 10/layer (§III-C2's L√T claim).
        let t_std = traffic_for(&crate::MNIST_ARCH, &method_for(Organization::Standard));
        let t_dm = traffic_for(&crate::MNIST_ARCH, &method_for(Organization::DmBnn));
        assert!(t_std.grng_samples > 9 * t_dm.grng_samples);
    }

    #[test]
    fn grng_energy_flag_increases_energy() {
        let cfg = AcceleratorConfig::paper_table5(Organization::Standard);
        let without = simulate(&cfg, false).energy_uj;
        let with = simulate(&cfg, true).energy_uj;
        assert!(with > without);
    }

    #[test]
    fn dm_moves_traffic_from_weights_to_beta() {
        let t_std = traffic_for(&crate::MNIST_ARCH, &method_for(Organization::Standard));
        let t_dm = traffic_for(&crate::MNIST_ARCH, &method_for(Organization::DmBnn));
        assert_eq!(t_std.beta_reads, 0);
        assert!(t_dm.weight_reads < t_std.weight_reads / 10);
        assert!(t_dm.beta_reads > 0);
        // total DM traffic must still be far below standard's
        let tot = |t: &Traffic| {
            t.weight_reads + t.beta_reads + t.beta_writes + t.act_reads + t.act_writes
        };
        assert!(tot(&t_dm) < tot(&t_std) / 2);
    }

    #[test]
    fn alpha_does_not_change_energy_or_runtime_materially() {
        // §IV: the memory-friendly framework trades memory, not compute.
        // (Leakage scales with area so energy shifts slightly; bound it.)
        let mut a = AcceleratorConfig::paper_table5(Organization::DmBnn);
        a.alpha = 1.0;
        let mut b = a.clone();
        b.alpha = 0.1;
        let ra = simulate(&a, false);
        let rb = simulate(&b, false);
        assert_eq!(ra.cycles, rb.cycles);
        // Energy shifts somewhat: smaller β banks have cheaper per-byte
        // reads (CACTI capacity term) and less leakage area; the compute
        // energy itself is identical.  Bound the drift.
        let rel = (ra.energy_uj - rb.energy_uj).abs() / ra.energy_uj;
        assert!(rel < 0.35, "alpha changed energy by {rel}");
        assert_eq!(ra.muls, rb.muls);
        assert_eq!(ra.adds, rb.adds);
    }
}
