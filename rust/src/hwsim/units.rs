//! 45 nm unit-cost constants.
//!
//! Arithmetic energies/areas follow Horowitz, "Computing's energy problem
//! (and what we can do about it)", ISSCC 2014 (45 nm, 0.9 V): 8-bit add
//! 0.03 pJ / 36 µm², 8-bit multiply 0.2 pJ / 282 µm².  The paper's cycle
//! model (§III-C1) is kept verbatim: ADD = 1 cycle, MUL = 2 cycles.
//! Clock and leakage are representative of 45 nm embedded accelerators.

/// Energy of one 8-bit fixed-point addition (pJ).
pub const ADD8_ENERGY_PJ: f64 = 0.03;
/// Energy of one 8-bit fixed-point multiplication (pJ).
pub const MUL8_ENERGY_PJ: f64 = 0.2;
/// Area of one 8-bit adder (µm²).
pub const ADD8_AREA_UM2: f64 = 36.0;
/// Area of one 8-bit multiplier (µm²).
pub const MUL8_AREA_UM2: f64 = 282.0;

/// Paper cycle model: one addition per cycle...
pub const ADD_CYCLES: u64 = 1;
/// ...and one multiplication per two cycles.
pub const MUL_CYCLES: u64 = 2;

/// Accelerator clock (MHz) — representative 45 nm embedded design point.
pub const CLOCK_MHZ: f64 = 200.0;

/// Leakage power per mm² of logic+SRAM at 45 nm (mW/mm²).
pub const LEAKAGE_MW_PER_MM2: f64 = 1.5;

/// CLT-12 GRNG: 12 LFSR taps + adder tree folded into one sample cost.
/// Energy per Gaussian sample (pJ) and area per generator (µm²).
pub const GRNG_SAMPLE_ENERGY_PJ: f64 = 0.4;
pub const GRNG_AREA_UM2: f64 = 1200.0;

/// Control / NoC / pipeline-register overhead as a fraction of core area.
pub const CONTROL_AREA_OVERHEAD: f64 = 0.20;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mul_costs_more_than_add() {
        assert!(MUL8_ENERGY_PJ > ADD8_ENERGY_PJ);
        assert!(MUL8_AREA_UM2 > ADD8_AREA_UM2);
        assert_eq!(MUL_CYCLES, 2 * ADD_CYCLES);
    }

    #[test]
    fn sane_magnitudes() {
        // Guard against unit slips (pJ vs nJ, µm² vs mm²).
        assert!(MUL8_ENERGY_PJ < 1.0);
        assert!(MUL8_AREA_UM2 < 1e4);
        assert!((50.0..=2000.0).contains(&CLOCK_MHZ));
    }
}
