//! CACTI-style SRAM macro model (45 nm).
//!
//! The paper uses CACTI [46] for memory area/energy.  This is a compact
//! analytic stand-in: bit-cell array + peripheral overhead that grows
//! with capacity (sense amps, decoders), and access energy with a
//! capacity-dependent wordline/bitline term.  Constants chosen to sit in
//! the published CACTI 6.0 45 nm range for 8–512 KB scratchpads.

/// 45 nm 6T bit-cell area (µm²/bit), including array efficiency.
const BITCELL_UM2: f64 = 0.45;
/// Fixed peripheral area per macro (µm²).
const MACRO_FIXED_UM2: f64 = 15_000.0;
/// Peripheral area fraction (decoders/sense amps) relative to the array.
const PERIPHERAL_FRAC: f64 = 0.35;

/// Base dynamic read energy per byte (pJ) for a small macro...
const READ_PJ_PER_BYTE_BASE: f64 = 0.8;
/// ...plus this much per log2(KB) of capacity (longer bitlines).
const READ_PJ_PER_BYTE_LOG: f64 = 0.25;
/// Writes cost slightly more than reads.
const WRITE_FACTOR: f64 = 1.2;

/// One SRAM bank of a given byte capacity.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SramBank {
    pub bytes: u64,
}

impl SramBank {
    pub fn new(bytes: u64) -> Self {
        Self { bytes }
    }

    /// Macro area in mm².
    pub fn area_mm2(&self) -> f64 {
        if self.bytes == 0 {
            return 0.0;
        }
        let array = self.bytes as f64 * 8.0 * BITCELL_UM2;
        (array * (1.0 + PERIPHERAL_FRAC) + MACRO_FIXED_UM2) / 1e6
    }

    /// Dynamic read energy for one byte (pJ).
    pub fn read_energy_pj_per_byte(&self) -> f64 {
        let kb = (self.bytes as f64 / 1024.0).max(1.0);
        READ_PJ_PER_BYTE_BASE + READ_PJ_PER_BYTE_LOG * kb.log2().max(0.0)
    }

    /// Dynamic write energy for one byte (pJ).
    pub fn write_energy_pj_per_byte(&self) -> f64 {
        self.read_energy_pj_per_byte() * WRITE_FACTOR
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn area_monotone_in_capacity() {
        let a = SramBank::new(16 * 1024).area_mm2();
        let b = SramBank::new(64 * 1024).area_mm2();
        let c = SramBank::new(256 * 1024).area_mm2();
        assert!(a < b && b < c);
    }

    #[test]
    fn area_in_cacti_45nm_ballpark() {
        // CACTI 6.0 45 nm: a 256 KB scratchpad is on the order of 1 mm².
        let a = SramBank::new(256 * 1024).area_mm2();
        assert!(a > 0.3 && a < 3.0, "256KB area {a} mm2");
        // 400 KB (the paper's weight store) should be 1–5 mm².
        let w = SramBank::new(400 * 1024).area_mm2();
        assert!(w > 0.5 && w < 5.0, "400KB area {w} mm2");
    }

    #[test]
    fn read_energy_grows_with_capacity() {
        let small = SramBank::new(8 * 1024).read_energy_pj_per_byte();
        let big = SramBank::new(512 * 1024).read_energy_pj_per_byte();
        assert!(big > small);
        assert!(small >= 0.8 && big < 5.0);
    }

    #[test]
    fn zero_bank_is_free() {
        let z = SramBank::new(0);
        assert_eq!(z.area_mm2(), 0.0);
    }

    #[test]
    fn writes_cost_more() {
        let b = SramBank::new(32 * 1024);
        assert!(b.write_energy_pj_per_byte() > b.read_energy_pj_per_byte());
    }
}
