//! Bench: Table IV — software implementation results.
//!
//! Runs the three inference methods (Standard/Hybrid T=100, DM-BNN
//! 10×10×10) over the served test set with the pure-rust reference
//! implementation, reporting accuracy plus *measured* (instrumented)
//! #MUL/#ADD — which must equal the analytic model — and per-image time.
//!
//! Requires `make artifacts` (skips politely otherwise).
//!
//! Emits `BENCH_table4.json` at the repo root (shared `common` emitter).

mod common;

use bayesdm::dataset::{load_images, load_weights};
use bayesdm::grng::uniform::XorShift128Plus;
use bayesdm::grng::Ziggurat;
use bayesdm::nn::bnn::{BnnModel, Method};
use bayesdm::opcount::model::{CostModel, Method as CostMethod};
use bayesdm::opcount::report::{render_table4, table4_rows};
use bayesdm::util::bench::header;
use bayesdm::MNIST_ARCH;

fn main() {
    header("Table IV — software implementation results");
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("SKIP: run `make artifacts` first");
        common::emit_bench_json(
            "table4",
            &common::json_doc("table4", &[("have_artifacts", "false".into())], &[]),
        );
        return;
    }
    let weights = load_weights("artifacts/weights_mnist_bnn.bin").unwrap();
    let test = load_images("artifacts/data_mnist_test.bin").unwrap();
    let n = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(100usize)
        .min(test.len());
    let model = BnnModel::new(weights);
    let cm = CostModel::from_arch(&MNIST_ARCH);

    let configs: [(&str, Method, CostMethod); 3] = [
        (
            "Standard BNN",
            Method::Standard { t: 100 },
            CostMethod::Standard { t: 100 },
        ),
        ("Hybrid-BNN", Method::Hybrid { t: 100 }, CostMethod::Hybrid { t: 100 }),
        (
            "DM-BNN",
            Method::DmBnn { schedule: vec![10, 10, 10] },
            CostMethod::DmBnn { schedule: vec![10, 10, 10] },
        ),
    ];

    let mut accs: Vec<Option<f64>> = Vec::new();
    let mut rows: Vec<String> = Vec::new();
    println!("evaluating {n} test images per method (pure-rust reference):\n");
    println!(
        "  {:<14} {:>9} {:>12} {:>12} {:>10} {:>12}",
        "Method", "Accuracy", "#MUL (1e6)", "#ADD (1e6)", "ms/img", "ops==model"
    );
    for (name, method, cost_method) in &configs {
        let mut g = Ziggurat::new(XorShift128Plus::new(7));
        let t0 = std::time::Instant::now();
        let mut correct = 0usize;
        let mut measured = bayesdm::opcount::OpCounter::default();
        for i in 0..n {
            let x = test.image(i);
            let (logits, ops) = model.evaluate(x, method, &mut g);
            measured = ops; // per-image counts are identical across images
            let mean = bayesdm::coordinator::vote::mean_vote(&logits);
            if bayesdm::coordinator::vote::argmax(&mean) == test.labels[i] as usize {
                correct += 1;
            }
        }
        let dt = t0.elapsed();
        let acc = correct as f64 / n as f64;
        accs.push(Some(acc));
        let want = cm.cost(cost_method, 1.0).total;
        println!(
            "  {:<14} {:>8.2}% {:>12.1} {:>12.1} {:>10.1} {:>12}",
            name,
            100.0 * acc,
            measured.muls as f64 / 1e6,
            measured.adds as f64 / 1e6,
            dt.as_millis() as f64 / n as f64,
            if measured == want { "exact" } else { "MISMATCH" },
        );
        assert_eq!(measured, want, "instrumented counts must equal the model");
        rows.push(format!(
            "{{\"method\": \"{name}\", \"accuracy\": {acc:.4}, \"muls\": {}, \"adds\": {}, \
             \"ms_per_img\": {:.2}}}",
            measured.muls,
            measured.adds,
            dt.as_millis() as f64 / n as f64
        ));
    }

    println!("\nanalytic table (accuracy columns = measured above):");
    println!("{}", render_table4(&table4_rows(), &accs));
    println!("paper reference: 96.73% / 96.73% / 96.7%, 39.8 / 24.2 / 6.9 Mmul");
    println!("(DM-BNN MULs land at ~9.1e6 under exact fan-out accounting — see DESIGN.md §6)");
    common::emit_bench_json(
        "table4",
        &common::json_doc(
            "table4",
            &[("have_artifacts", "true".into()), ("images", n.to_string())],
            &rows,
        ),
    );
}
