//! Bench: the α-blocked, allocation-free multi-voter kernel core vs the
//! seed's per-voter dataflow.
//!
//! Two rungs per batch size on dm 2×2×2 (plus an α sweep):
//!
//! * `per-voter` — the pre-kernel-core shape: shared banks, but every
//!   voter allocates its own activation/β/η vectors and sweeps full rows
//!   (a faithful reconstruction of the old `evaluate_with_banks` loop).
//! * `fused α=…` — the plan-compiled executor: one scratch arena reused
//!   across the whole stream, flat logit output, and each β/H row block
//!   feeding every voter while resident.
//!
//! Both paths are asserted bit-identical before timing.  Acceptance
//! shape: the fused blocked sweep beats the per-voter baseline on dm
//! 2×2×2 for every batch ≥ 16 (single-threaded, so the win is the kernel
//! core, not the worker pool).
//!
//! Emits `BENCH_kernels.json` at the repo root for the perf trajectory
//! (machine-readable mirror of the printed table).

mod common;

use std::time::Duration;

use bayesdm::dataset::{SynthSpec, Synthesizer};
use bayesdm::grng::default_grng;
use bayesdm::nn::batch::evaluate_batch_planned;
use bayesdm::nn::bnn::{BnnModel, Method, UncertaintyBanks};
use bayesdm::nn::linear::{dm_voter, precompute};
use bayesdm::nn::plan::{DataflowPlan, ScratchPool};
use bayesdm::opcount::OpCounter;
use bayesdm::util::bench::{bench_for, header, Measurement};
use bayesdm::MNIST_ARCH;

/// The seed-shaped per-voter DM evaluation: full-row sweeps, fresh heap
/// vectors for every activation, β, η and voter output.
fn per_voter_dm(
    model: &BnnModel,
    x: &[f32],
    banks: &UncertaintyBanks,
    ops: &mut OpCounter,
) -> Vec<Vec<f32>> {
    let nl = model.layers.len();
    let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
    for li in 0..nl {
        let l = &model.layers[li];
        let relu = li != nl - 1;
        let mut next = Vec::with_capacity(acts.len() * banks[li].len());
        for a in &acts {
            let mut beta = vec![0.0f32; l.m * l.n];
            let mut eta = vec![0.0f32; l.m];
            precompute(l, a, &mut beta, &mut eta, ops);
            for (h, hb) in &banks[li] {
                let mut y = vec![0.0f32; l.m];
                dm_voter(l, &beta, &eta, h, hb, 0, relu, &mut y, ops);
                next.push(y);
            }
        }
        acts = next;
    }
    acts
}

struct Row {
    case: String,
    batch: usize,
    alpha: f64,
    inputs_per_sec: f64,
    mean_ms: f64,
}

fn to_json(rows: &[Row]) -> String {
    let fields = [
        ("method", "\"dm_2x2x2\"".to_string()),
        ("arch", format!("[{}]", MNIST_ARCH.map(|d| d.to_string()).join(","))),
    ];
    let rendered: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"case\": \"{}\", \"batch\": {}, \"alpha\": {}, \"inputs_per_sec\": {:.2}, \
                 \"mean_ms\": {:.4}}}",
                r.case, r.batch, r.alpha, r.inputs_per_sec, r.mean_ms
            )
        })
        .collect();
    common::json_doc("kernels", &fields, &rendered)
}

fn inputs_per_sec(batch: usize, m: &Measurement) -> f64 {
    batch as f64 / m.mean.as_secs_f64()
}

fn main() {
    header("Kernels — α-blocked fused multi-voter core vs per-voter baseline");
    let model = BnnModel::synthetic(&MNIST_ARCH, 0x5EED5);
    let method = Method::DmBnn { schedule: vec![2, 2, 2] };
    let data = Synthesizer::new(SynthSpec::mnist()).dataset(32);
    let all: Vec<Vec<f32>> = (0..data.len()).map(|i| data.image(i).to_vec()).collect();

    // Parity before timing: the fused blocked executor reproduces the
    // per-voter baseline bit-for-bit at every α.
    {
        let mut g = default_grng(42);
        let banks = model.sample_banks(&method, &mut g);
        let mut ops = OpCounter::default();
        let want = per_voter_dm(&model, &all[0], &banks, &mut ops);
        for alpha in [1.0, 0.5, 0.1] {
            let plan = DataflowPlan::with_alpha(&model, &method, alpha);
            let mut g = default_grng(42);
            let got = evaluate_batch_planned(&model, &plan, &all[..1], &mut g, 1, None, None);
            assert_eq!(got.logits.input(0).to_vecs(), want, "alpha={alpha}");
        }
        println!("parity: fused blocked executor == per-voter baseline (all α)\n");
    }

    let budget = Duration::from_millis(400);
    let pool = ScratchPool::new();
    let mut rows: Vec<Row> = Vec::new();
    let mut headline: Vec<(usize, f64, f64)> = Vec::new();

    for &bs in &[1usize, 8, 16, 32] {
        let xs = &all[..bs];
        let m_base = bench_for(&format!("per-voter    b={bs}"), budget, || {
            let mut g = default_grng(42);
            let banks = model.sample_banks(&method, &mut g);
            let mut ops = OpCounter::default();
            for x in xs {
                std::hint::black_box(per_voter_dm(&model, x, &banks, &mut ops));
            }
        });
        let base_ips = inputs_per_sec(bs, &m_base);
        rows.push(Row {
            case: "per_voter_baseline".into(),
            batch: bs,
            alpha: 1.0,
            inputs_per_sec: base_ips,
            mean_ms: m_base.mean_ms(),
        });

        let mut fused_full = 0.0f64;
        for &alpha in &[1.0f64, 0.5, 0.1] {
            let plan = DataflowPlan::with_alpha(&model, &method, alpha);
            let m_fused = bench_for(&format!("fused α={alpha:<4} b={bs}"), budget, || {
                let mut g = default_grng(42);
                let r = evaluate_batch_planned(&model, &plan, xs, &mut g, 1, None, Some(&pool));
                std::hint::black_box(r);
            });
            let ips = inputs_per_sec(bs, &m_fused);
            if alpha == 1.0 {
                fused_full = ips;
            }
            rows.push(Row {
                case: "fused_blocked".into(),
                batch: bs,
                alpha,
                inputs_per_sec: ips,
                mean_ms: m_fused.mean_ms(),
            });
            println!(
                "  b={bs:<3} α={alpha:<4} fused {ips:>9.1} in/s | per-voter {base_ips:>9.1} \
                 in/s ({:4.2}x)",
                ips / base_ips
            );
        }
        headline.push((bs, base_ips, fused_full));
        println!();
    }

    let json = to_json(&rows);
    common::emit_bench_json("kernels", &json);
    println!("({} rows)", rows.len());

    for &(bs, base, fused) in &headline {
        if bs >= 16 {
            assert!(
                fused > base,
                "acceptance: fused multi-voter sweep must beat the per-voter \
                 baseline on dm 2x2x2 at batch {bs}: {fused:.1} vs {base:.1} inputs/sec"
            );
        }
    }
    println!("OK: fused blocked sweep beats per-voter baseline for every batch >= 16");
}
