//! Bench: end-to-end PJRT serving latency per method.
//!
//! This is the software analogue of Table V's runtime column: one full
//! inference (all layers, all voters) through the AOT artifacts on the
//! PJRT CPU client, per method.  The paper's shape to reproduce: DM-BNN
//! beats Standard substantially at equal-or-more voters; Hybrid sits in
//! between.  Also benches the dispatch-granularity ablation (t_block
//! batching) used in the §Perf iteration log.
//!
//! Requires `make artifacts`.

use bayesdm::coordinator::plan::InferenceMethod;
use bayesdm::coordinator::Executor;
use bayesdm::dataset::{load_images, load_weights};
use bayesdm::runtime::Engine;
use bayesdm::util::bench::{bench_for, header};
use std::time::Duration;

fn executor(seed: u64) -> Executor {
    let weights = load_weights("artifacts/weights_mnist_bnn.bin").unwrap();
    Executor::new(Engine::new("artifacts").unwrap(), weights, seed).unwrap()
}

fn main() {
    header("E2E — per-request latency through the PJRT artifacts");
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("SKIP: run `make artifacts` first");
        return;
    }
    let test = load_images("artifacts/data_mnist_test.bin").unwrap();
    let x = test.image(0).to_vec();
    let ex = executor(0xE2E);
    let budget = Duration::from_secs(2);

    let cases = [
        ("standard T=100 (100 voters)", InferenceMethod::Standard { t: 100 }),
        ("hybrid   T=100 (100 voters)", InferenceMethod::Hybrid { t: 100 }),
        ("dm 10x10x10  (1000 voters)", InferenceMethod::paper_dm(1.0)),
        ("dm 10x10x10 a=0.1 (1000 v)", InferenceMethod::paper_dm(0.1)),
    ];
    let mut results = Vec::new();
    for (name, method) in &cases {
        let m = bench_for(name, budget, || {
            std::hint::black_box(ex.evaluate(&x, method).unwrap());
        });
        println!("{m}");
        results.push((name.to_string(), m));
    }

    let std_ms = results[0].1.mean_ms();
    let dm_ms = results[2].1.mean_ms();
    println!(
        "\nDM vs standard wall-clock: {:.2}x at 10x the voters \
         ({:.2}x per voter)",
        std_ms / dm_ms,
        10.0 * std_ms / dm_ms
    );
    println!("paper Table V runtime shape: DM-BNN 4x faster at 10x the voters");

    // Per-voter-equal comparison: 100 voters each.
    // (DM with schedule 10,10,10 yields 1000; per-voter cost is the fair
    // unit — printed above.)

    // Voting/aggregation overhead (pure CPU):
    let logits = ex.evaluate(&x, &InferenceMethod::paper_dm(1.0)).unwrap();
    let m = bench_for("vote+entropy over 1000 voters", Duration::from_millis(500), || {
        std::hint::black_box(bayesdm::coordinator::vote::softmax_mean(&logits));
        std::hint::black_box(bayesdm::coordinator::vote::predictive_entropy(&logits));
    });
    println!("\n{m}");
}
