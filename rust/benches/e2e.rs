//! Bench: end-to-end serving latency/throughput through the router +
//! batched reference engine.
//!
//! The full request path — admission, micro-batching, engine dispatch,
//! voting, response — on the self-contained synthetic model and dataset,
//! so it runs with zero artifact dependencies.  Reports req/s and the
//! p50/p99 latency split per method, and the effect of the router's
//! micro-batch size (the dynamic-batching win).
//!
//! Emits `BENCH_e2e.json` at the repo root (shared `common` emitter).

mod common;

use std::sync::Arc;
use std::time::Instant;

use bayesdm::coordinator::engine::default_workers;
use bayesdm::coordinator::plan::InferenceMethod;
use bayesdm::coordinator::{serve_engine, Engine, EngineConfig, ServerConfig};
use bayesdm::dataset::{SynthSpec, Synthesizer};
use bayesdm::nn::bnn::BnnModel;
use bayesdm::util::bench::header;
use bayesdm::MNIST_ARCH;

fn engine() -> Arc<Engine> {
    let model = BnnModel::synthetic(&MNIST_ARCH, 0xE2E);
    Arc::new(Engine::new(
        model,
        EngineConfig { workers: default_workers(), seed: 0xE2E, ..EngineConfig::default() },
    ))
}

/// Serve `requests` images through a fresh server; returns (req/s, p50 µs,
/// p99 µs).
fn round(images: &[Vec<f32>], method: &InferenceMethod, max_batch: usize) -> (f64, u64, u64) {
    // One dispatch worker: the shared engine's pool is the parallelism.
    let cfg = ServerConfig { max_batch, workers: 1, ..ServerConfig::default() };
    let handle = serve_engine(engine(), cfg);
    let t0 = Instant::now();
    let pending: Vec<_> = images
        .iter()
        .map(|x| handle.classify(x.clone(), method.clone()).expect("submit"))
        .collect();
    for p in pending {
        p.wait().expect("response");
    }
    let dt = t0.elapsed().as_secs_f64();
    let p50 = handle.metrics.latency_percentile_us(0.50).unwrap_or(0);
    let p99 = handle.metrics.latency_percentile_us(0.99).unwrap_or(0);
    handle.shutdown();
    (images.len() as f64 / dt, p50, p99)
}

fn main() {
    header("E2E — serving latency/throughput (batched reference engine)");
    println!("engine pool: {} threads\n", default_workers());
    let data = Synthesizer::new(SynthSpec::mnist()).dataset(96);
    let images: Vec<Vec<f32>> = (0..data.len()).map(|i| data.image(i).to_vec()).collect();

    let cases = [
        ("standard T=8  ( 8 voters)", InferenceMethod::Standard { t: 8 }),
        ("hybrid   T=8  ( 8 voters)", InferenceMethod::Hybrid { t: 8 }),
        (
            "dm 2x2x2      ( 8 voters)",
            InferenceMethod::DmBnn { schedule: vec![2, 2, 2], alpha: 1.0 },
        ),
    ];
    let mut rows: Vec<String> = Vec::new();
    let row = |method: &str, mb: usize, rps: f64, p50: u64, p99: u64| {
        format!(
            "{{\"method\": \"{method}\", \"max_batch\": {mb}, \"req_per_sec\": {rps:.1}, \
             \"p50_us\": {p50}, \"p99_us\": {p99}}}"
        )
    };
    for (name, method) in &cases {
        let (rps, p50, p99) = round(&images, method, 8);
        println!("{name}: {rps:8.1} req/s  p50 {p50:>6} µs  p99 {p99:>6} µs");
        rows.push(row(name.split_whitespace().next().unwrap_or(name), 8, rps, p50, p99));
    }

    println!("\nmicro-batch size sweep (dm 2x2x2):");
    let dm = InferenceMethod::DmBnn { schedule: vec![2, 2, 2], alpha: 1.0 };
    let mut first = 0.0f64;
    for &mb in &[1usize, 4, 16, 32] {
        let (rps, p50, p99) = round(&images, &dm, mb);
        if mb == 1 {
            first = rps;
        }
        println!(
            "  max_batch={mb:<3} {rps:8.1} req/s  ({:4.2}x vs unbatched)  \
             p50 {p50:>6} µs  p99 {p99:>6} µs",
            rps / first
        );
        rows.push(row("dm_batch_sweep", mb, rps, p50, p99));
    }
    println!(
        "\nbigger micro-batches amortize the per-batch Θ sampling across \
         more requests (the engine-level memoization win)."
    );
    common::emit_bench_json(
        "e2e",
        &common::json_doc(
            "e2e",
            &[
                ("requests", images.len().to_string()),
                ("workers", default_workers().to_string()),
            ],
            &rows,
        ),
    );
}
