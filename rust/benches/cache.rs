//! Bench: cross-request feature-decomposition cache — duplicate-rate ×
//! cache-size sweep on a synthetic serving stream.
//!
//! The stream replays batches where a fraction `rate` of the slots repeat
//! a small pool of hot images (think: trending inputs, retries, A/B
//! replays) and the rest are fresh never-seen images.  Cache-off pays the
//! full DM dataflow for every slot; cache-on skips the deterministic
//! precompute GEMVs for every repeat — layer 0 across batches (its keys
//! are the raw inputs) and deeper layers within a batch (duplicates share
//! the batch's banks, so their activations collide too).
//!
//! Every measured configuration is asserted bit-identical to cache-off
//! first, then timed.  Acceptance shape: on the 90%-duplicate stream with
//! a warm 64 MiB cache, throughput is ≥ 1.5× cache-off (the avoided-MUL
//! fraction for dm 2x2x2 is ~45%, so the arithmetic alone predicts ~1.8×).
//!
//! Emits `BENCH_cache.json` at the repo root (shared `common` emitter).

mod common;

use std::time::Duration;

use bayesdm::coordinator::{CacheConfig, Engine, EngineConfig};
use bayesdm::grng::split_seed;
use bayesdm::grng::uniform::{UniformSource, XorShift128Plus};
use bayesdm::nn::bnn::{BnnModel, Method};
use bayesdm::util::bench::{bench_for, header, Measurement};
use bayesdm::MNIST_ARCH;

const POOL: usize = 4; // hot images
const BATCH: usize = 32;
const BATCHES_PER_ITER: usize = 4;
const SEED: u64 = 0x0DE_CACE;

struct Stream {
    pool: Vec<Vec<f32>>,
    rng: XorShift128Plus,
    batch_idx: u64,
    rate_pct: usize,
}

impl Stream {
    fn new(rate_pct: usize) -> Self {
        let mut rng = XorShift128Plus::new(0xF00D);
        let dim = MNIST_ARCH[0];
        let pool = (0..POOL)
            .map(|_| (0..dim).map(|_| rng.next_f32()).collect())
            .collect();
        Self { pool, rng, batch_idx: 0, rate_pct }
    }

    /// Next micro-batch: `rate_pct`% of slots cycle the hot pool, the
    /// rest are fresh images never seen before (so layer-0 entries for
    /// them are useless — honest churn against the cache).
    fn next_batch(&mut self) -> (Vec<Vec<f32>>, u64) {
        let dim = MNIST_ARCH[0];
        let xs = (0..BATCH)
            .map(|slot| {
                if slot * 100 < self.rate_pct * BATCH {
                    self.pool[slot % POOL].clone()
                } else {
                    (0..dim).map(|_| self.rng.next_f32()).collect()
                }
            })
            .collect();
        let seed = split_seed(SEED, self.batch_idx);
        self.batch_idx += 1;
        (xs, seed)
    }
}

fn engine(cache: CacheConfig) -> Engine {
    Engine::new(
        BnnModel::synthetic(&MNIST_ARCH, 0x7A57E),
        EngineConfig { workers: 1, seed: SEED, cache, ..EngineConfig::default() },
    )
}

fn run_stream(e: &Engine, method: &Method, stream: &mut Stream) {
    for _ in 0..BATCHES_PER_ITER {
        let (xs, seed) = stream.next_batch();
        std::hint::black_box(e.evaluate_batch_seeded(&xs, method, seed));
    }
}

fn inputs_per_sec(m: &Measurement) -> f64 {
    (BATCH * BATCHES_PER_ITER) as f64 / m.mean.as_secs_f64()
}

fn main() {
    header("Feature-decomposition cache — duplicate-rate × cache-size sweep");
    let method = Method::DmBnn { schedule: vec![2, 2, 2] };
    println!("arch {MNIST_ARCH:?}, dm 2x2x2, batch {BATCH}, hot pool {POOL}\n");

    // Parity spot-check before timing anything: cache-on replay of the
    // same stream prefix is bit-identical to cache-off.
    {
        let off = engine(CacheConfig::disabled());
        let on = engine(CacheConfig::with_mb(64));
        let mut sa = Stream::new(90);
        let mut sb = Stream::new(90);
        for _ in 0..3 {
            let (xs, seed) = sa.next_batch();
            let (ys, seed_b) = sb.next_batch();
            assert_eq!(seed, seed_b);
            let a = off.evaluate_batch_seeded(&xs, &method, seed);
            let b = on.evaluate_batch_seeded(&ys, &method, seed);
            assert_eq!(a.logits, b.logits, "cache changed results");
            assert_eq!(a.ops.muls, b.ops.muls, "cache under-counted logical muls");
        }
        println!("parity: cache-on logits and logical op counts bit-identical\n");
    }

    let budget = Duration::from_millis(500);
    let mut headline: Option<(f64, f64)> = None;
    let mut rows: Vec<String> = Vec::new();
    let row = |rate: usize, mb: usize, ips: f64, speedup: f64| {
        format!(
            "{{\"duplicate_rate_pct\": {rate}, \"cache_mb\": {mb}, \
             \"inputs_per_sec\": {ips:.1}, \"speedup_vs_off\": {speedup:.3}}}"
        )
    };

    for &rate in &[0usize, 50, 90] {
        println!("duplicate rate {rate}%:");
        let mut stream = Stream::new(rate);
        let e_off = engine(CacheConfig::disabled());
        let m_off = bench_for(&format!("cache off      rate={rate}%"), budget, || {
            run_stream(&e_off, &method, &mut stream)
        });
        let off_ips = inputs_per_sec(&m_off);
        rows.push(row(rate, 0, off_ips, 1.0));

        for &mb in &[8usize, 64] {
            let e_on = engine(CacheConfig::with_mb(mb));
            let mut stream = Stream::new(rate);
            // warm the hot-pool entries before measuring
            run_stream(&e_on, &method, &mut stream);
            let m_on = bench_for(&format!("cache {mb:>3} MiB  rate={rate}%"), budget, || {
                run_stream(&e_on, &method, &mut stream)
            });
            let on_ips = inputs_per_sec(&m_on);
            let stats = e_on.cache_stats().expect("cache enabled");
            let label = format!("{mb} MiB");
            println!(
                "  {label:<22} {on_ips:>9.1} in/s | off {off_ips:>9.1} in/s | {:>5.2}x | {stats}",
                on_ips / off_ips,
            );
            rows.push(row(rate, mb, on_ips, on_ips / off_ips));
            if rate == 90 && mb == 64 {
                headline = Some((off_ips, on_ips));
            }
        }
        println!();
    }

    let (off_ips, on_ips) = headline.expect("headline config measured");
    let speedup = on_ips / off_ips;
    println!(
        "headline: 90% duplicates, warm 64 MiB cache: {speedup:.2}x vs cache-off \
         ({on_ips:.1} vs {off_ips:.1} inputs/sec)"
    );
    common::emit_bench_json(
        "cache",
        &common::json_doc(
            "cache",
            &[
                ("batch", BATCH.to_string()),
                ("hot_pool", POOL.to_string()),
                ("headline_speedup_64mb_rate90", format!("{speedup:.3}")),
            ],
            &rows,
        ),
    );
    assert!(
        speedup >= 1.5,
        "acceptance: warm cache on the 90%-duplicate stream must be >= 1.5x \
         cache-off, measured {speedup:.2}x"
    );
    println!("OK: >= 1.5x on the 90%-duplicate stream with a warm cache");
}
