//! Bench: Table V — hardware implementation results.
//!
//! Simulates the three accelerator organizations on the 45 nm cost model
//! (area / energy / runtime) and measures the 8-bit fixed-point accuracy
//! with the quantized functional model — the full Table V row set, plus
//! the ratio columns the paper's abstract quotes (−73 % energy, 4×
//! speedup, +14 % area).
//!
//! Emits `BENCH_table5.json` at the repo root (shared `common` emitter).

mod common;

use bayesdm::dataset::{load_images, load_weights};
use bayesdm::grng::uniform::XorShift128Plus;
use bayesdm::grng::Ziggurat;
use bayesdm::hwsim::report::{render_table5, table5_rows};
use bayesdm::nn::bnn::Method;
use bayesdm::nn::fixed_infer::QBnnModel;
use bayesdm::util::bench::header;

fn main() {
    header("Table V — hardware implementation results (45 nm model)");
    let have_artifacts = std::path::Path::new("artifacts/manifest.json").exists();

    let mut accs: [Option<f64>; 3] = [None, None, None];
    if have_artifacts {
        let weights = load_weights("artifacts/weights_mnist_bnn.bin").unwrap();
        let test = load_images("artifacts/data_mnist_test.bin").unwrap();
        let n = std::env::args()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .unwrap_or(60usize)
            .min(test.len());
        let q = QBnnModel::from_posterior(&weights);
        let methods = [
            Method::Standard { t: 100 },
            Method::Hybrid { t: 100 },
            Method::DmBnn { schedule: vec![10, 10, 10] },
        ];
        println!("quantized (8-bit) accuracy over {n} images:");
        for (i, m) in methods.iter().enumerate() {
            let mut g = Ziggurat::new(XorShift128Plus::new(13 + i as u64));
            let t0 = std::time::Instant::now();
            let acc =
                q.accuracy(&test.images[..n * test.dim], &test.labels[..n], m, &mut g);
            println!(
                "  method {} -> {:.2}% ({:.1} ms/img)",
                i,
                100.0 * acc,
                t0.elapsed().as_millis() as f64 / n as f64
            );
            accs[i] = Some(acc);
        }
    } else {
        println!("(artifacts missing: accuracy columns skipped — run `make artifacts`)");
    }

    let rows = table5_rows(&accs);
    println!("\n{}", render_table5(&rows));
    println!("paper reference:");
    println!("  Standard 95.42%  5.76 mm²  172 µJ  392 µs");
    println!("  Hybrid   95.42%  7.33 mm²  122 µJ  259 µs  (−29% E, 1.5×)");
    println!("  DM-BNN   95.35%  6.63 mm²   46 µJ   97 µs  (−73% E, 4.0×)");

    let rendered: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"method\": \"{}\", \"accuracy\": {}, \"area_mm2\": {:.4}, \
                 \"energy_uj\": {:.2}, \"runtime_us\": {:.2}}}",
                r.method,
                r.accuracy.map_or("null".to_string(), |a| format!("{a:.4}")),
                r.area_mm2,
                r.energy_uj,
                r.runtime_us
            )
        })
        .collect();
    common::emit_bench_json(
        "table5",
        &common::json_doc(
            "table5",
            &[("have_artifacts", have_artifacts.to_string())],
            &rendered,
        ),
    );
}
