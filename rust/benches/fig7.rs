//! Bench: Fig 7 — system area vs α (memory-friendly framework).
//!
//! Sweeps α over the DM-BNN organization, asserts monotonicity (the
//! figure's claim) and prints the β-SRAM share so the mechanism is
//! visible; also times the hwsim evaluation itself.
//!
//! Emits `BENCH_fig7.json` at the repo root (shared `common` emitter).

mod common;

use bayesdm::hwsim::arch::{AcceleratorConfig, Organization};
use bayesdm::hwsim::report::{fig7_rows, render_fig7};
use bayesdm::hwsim::sim::simulate;
use bayesdm::util::bench::{bench, header};

fn main() {
    header("Fig 7 — system area vs alpha");
    let alphas = [1.0, 0.9, 0.8, 0.7, 0.6, 0.5, 0.4, 0.3, 0.2, 0.1, 0.05];
    let rows = fig7_rows(&alphas);
    println!("{}", render_fig7(&rows));

    // Monotonicity assertion (the figure's core claim).
    for w in rows.windows(2) {
        assert!(
            w[1].area_mm2 < w[0].area_mm2,
            "area must decrease with alpha: {:?} -> {:?}",
            w[0],
            w[1]
        );
    }
    println!("monotone: OK (area strictly decreases as alpha shrinks)");

    // Mechanism breakdown: β-SRAM area share per alpha.
    println!("\nβ-SRAM share of total area:");
    for &alpha in &[1.0, 0.5, 0.2, 0.1] {
        let mut cfg = AcceleratorConfig::paper_table5(Organization::DmBnn);
        cfg.alpha = alpha;
        let beta: f64 = cfg.beta_srams().iter().map(|b| b.area_mm2()).sum();
        let total = cfg.area_mm2();
        println!(
            "  α={alpha:<5} β-SRAM {beta:>6.3} mm² / total {total:>6.3} mm² = {:>4.1}%",
            100.0 * beta / total
        );
    }

    // Compute-neutrality check (§IV): cycles identical across alpha.
    let base = simulate(&AcceleratorConfig::paper_table5(Organization::DmBnn), false);
    let mut cfg = AcceleratorConfig::paper_table5(Organization::DmBnn);
    cfg.alpha = 1.0;
    let full = simulate(&cfg, false);
    assert_eq!(base.cycles, full.cycles);
    println!("\ncompute-neutral: OK (cycles identical at α=0.1 and α=1.0)");

    let m = bench("hwsim simulate (one design point)", 2, 50, || {
        std::hint::black_box(simulate(
            &AcceleratorConfig::paper_table5(Organization::DmBnn),
            false,
        ));
    });
    println!("\n{m}");

    let rendered: Vec<String> = rows
        .iter()
        .map(|r| format!("{{\"alpha\": {}, \"area_mm2\": {:.4}}}", r.alpha, r.area_mm2))
        .collect();
    common::emit_bench_json(
        "fig7",
        &common::json_doc(
            "fig7",
            &[("simulate_ms", format!("{:.4}", m.mean_ms()))],
            &rendered,
        ),
    );
}
