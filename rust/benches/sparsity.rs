//! Bench: density-dispatched sparse sweeps + the bit-packed ±1 sign
//! backend, against their dense counterparts.
//!
//! Section 1 sweeps activation density over the f32 DM layer: for each
//! density the sparse path (index build **included** in the timing, as
//! the dispatch pays it per layer call) is first asserted bit-identical
//! to the dense blocked sweep — logits and logical op counts — then
//! timed.  The measured crossover (largest tested density where sparse
//! is at least as fast as dense) is reported, and the bench asserts the
//! sparse win is ≥ 1.5× somewhere at ≥ 70% sparsity (density ≤ 0.30).
//!
//! Section 2 times the packed ±1 XOR/popcount backend against the i8
//! fixed-point kernels on all-±1 tensors at the frac-0 format, where the
//! two are exact over the same arithmetic (see DESIGN.md §14); parity is
//! asserted first, then the packed path must win by ≥ 2×.
//!
//! Emits `BENCH_sparsity.json` at the repo root (shared `common` emitter).

mod common;

use std::time::Duration;

use bayesdm::dataset::LayerPosterior;
use bayesdm::fixed::{sign_dm_layer, sign_precompute, SignBits, SignLayer, SignMatrix, SIGN_FMT};
use bayesdm::grng::uniform::{UniformSource, XorShift128Plus};
use bayesdm::nn::fixed_infer::QLayer;
use bayesdm::nn::kernels::{
    build_sparse_index, dm_layer_blocked, dm_layer_sparse, q_dm_layer_banked, q_precompute,
};
use bayesdm::nn::linear::precompute;
use bayesdm::nn::plan::TileGeometry;
use bayesdm::nn::simd::{self, LANES};
use bayesdm::opcount::OpCounter;
use bayesdm::util::bench::{bench_for, header};

const VOTERS: usize = 8;
const M: usize = 256;
const N: usize = 1024;
const DENSITIES: [f64; 8] = [1.0, 0.9, 0.7, 0.5, 0.3, 0.1, 0.05, 0.01];

/// Input with exactly `nnz` nonzero coordinates: positions from a
/// full-period stride walk (769 is odd, hence coprime with N = 1024),
/// values offset so they are never exactly zero.
fn input_at(nnz: usize, seed: u64) -> Vec<f32> {
    let mut r = XorShift128Plus::new(seed);
    let mut x = vec![0.0f32; N];
    for k in 0..nnz {
        x[(k * 769) % N] = 0.1 + r.next_f32();
    }
    x
}

fn layer(seed: u64) -> LayerPosterior {
    let mut r = XorShift128Plus::new(seed);
    LayerPosterior {
        m: M,
        n: N,
        mu: (0..M * N).map(|_| r.next_f32() - 0.5).collect(),
        sigma: (0..M * N).map(|_| 0.01 + 0.05 * r.next_f32()).collect(),
        mu_b: (0..M).map(|_| r.next_f32() - 0.5).collect(),
        sigma_b: (0..M).map(|_| 0.01 + 0.05 * r.next_f32()).collect(),
    }
}

fn pm1(len: usize, r: &mut XorShift128Plus) -> Vec<i8> {
    (0..len).map(|_| if r.next_u64() & 1 == 0 { 1i8 } else { -1 }).collect()
}

struct Row {
    density: f64,
    nnz: usize,
    dense_ms: f64,
    sparse_ms: f64,
    speedup: f64,
}

fn main() {
    header("Sparsity — density-dispatched sparse sweeps + packed ±1 sign backend");
    println!("kernel: {}  shape {M}x{N}, {VOTERS} voters\n", simd::isa_label());
    let budget = Duration::from_millis(300);

    // ---- Section 1: f32 DM layer, density sweep ------------------------
    let l = layer(0x5A7A);
    let mut r = XorShift128Plus::new(7);
    let bank: Vec<(Vec<f32>, Vec<f32>)> = (0..VOTERS)
        .map(|_| {
            (
                (0..M * N).map(|_| r.next_f32() * 2.0 - 1.0).collect(),
                (0..M).map(|_| r.next_f32() * 2.0 - 1.0).collect(),
            )
        })
        .collect();
    let block_rows = M.min(64);
    let tiles = TileGeometry::default();

    let mut rows: Vec<Row> = Vec::new();
    for (di, &density) in DENSITIES.iter().enumerate() {
        let nnz = ((N as f64) * density).round() as usize;
        let x = input_at(nnz, 0xD0 + di as u64);
        let mut beta = vec![0.0f32; M * N];
        let mut eta = vec![0.0f32; M];
        let mut ops = OpCounter::default();
        precompute(&l, &x, &mut beta, &mut eta, &mut ops);

        let mut nzmask = vec![0u64; N.div_ceil(64)];
        let mut spidx = vec![0i32; N + LANES];

        // parity gate before timing: sparse must be bit-identical to the
        // dense blocked sweep with the same logical op counts
        let mut want = vec![0.0f32; VOTERS * M];
        let mut dense_ops = OpCounter::default();
        dm_layer_blocked(
            &l,
            &beta,
            &eta,
            &bank,
            block_rows,
            tiles,
            true,
            &mut want,
            &mut dense_ops,
        );
        if let Some((idx_rows, got_nnz)) = build_sparse_index(&x, &mut nzmask, &mut spidx) {
            assert_eq!(got_nnz, nnz, "index nnz mismatch at density {density}");
            let mut got = vec![0.0f32; VOTERS * M];
            let mut sparse_ops = OpCounter::default();
            dm_layer_sparse(
                &l,
                &beta,
                &eta,
                &bank,
                true,
                &mut got,
                &spidx[..idx_rows * LANES],
                nnz,
                &mut sparse_ops,
            );
            let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&got), bits(&want), "density {density}: sparse logits must match");
            assert_eq!(sparse_ops.muls, dense_ops.muls, "density {density}: logical muls moved");
            assert_eq!(sparse_ops.adds, dense_ops.adds, "density {density}: logical adds moved");
        }

        let mut ys = vec![0.0f32; VOTERS * M];
        let m_dense = bench_for(&format!("dense  density={density:<4}"), budget, || {
            let mut ops = OpCounter::default();
            dm_layer_blocked(&l, &beta, &eta, &bank, block_rows, tiles, true, &mut ys, &mut ops);
            std::hint::black_box(&mut ys);
        });
        // sparse timing includes the per-call index build, exactly as the
        // runtime dispatch pays it
        let m_sparse = bench_for(&format!("sparse density={density:<4}"), budget, || {
            let mut ops = OpCounter::default();
            match build_sparse_index(&x, &mut nzmask, &mut spidx) {
                Some((idx_rows, nz)) => dm_layer_sparse(
                    &l,
                    &beta,
                    &eta,
                    &bank,
                    true,
                    &mut ys,
                    &spidx[..idx_rows * LANES],
                    nz,
                    &mut ops,
                ),
                None => dm_layer_blocked(
                    &l,
                    &beta,
                    &eta,
                    &bank,
                    block_rows,
                    tiles,
                    true,
                    &mut ys,
                    &mut ops,
                ),
            }
            std::hint::black_box(&mut ys);
        });
        let speedup = m_dense.mean.as_secs_f64() / m_sparse.mean.as_secs_f64();
        println!(
            "  density {density:<4} (nnz {nnz:>4}): dense {:>8.3} ms | sparse {:>8.3} ms  \
             ({speedup:4.2}x)\n",
            m_dense.mean_ms(),
            m_sparse.mean_ms()
        );
        rows.push(Row {
            density,
            nnz,
            dense_ms: m_dense.mean_ms(),
            sparse_ms: m_sparse.mean_ms(),
            speedup,
        });
    }

    let crossover = rows
        .iter()
        .filter(|r| r.speedup >= 1.0)
        .map(|r| r.density)
        .fold(0.0f64, f64::max);
    println!("measured crossover density: {crossover} (largest density where sparse >= dense)\n");

    // ---- Section 2: packed ±1 sign backend vs i8 fixed-point -----------
    let mut r = XorShift128Plus::new(0x516);
    let q = QLayer {
        m: M,
        n: N,
        mu: pm1(M * N, &mut r),
        sigma: pm1(M * N, &mut r),
        mu_b: pm1(M, &mut r),
        sigma_b: pm1(M, &mut r),
        wfmt: SIGN_FMT,
    };
    let xq = pm1(N, &mut r);
    let qbank: Vec<(Vec<i8>, Vec<i8>)> =
        (0..VOTERS).map(|_| (pm1(M * N, &mut r), pm1(M, &mut r))).collect();
    let sl = SignLayer::binarize(&q);
    let xs = SignBits::pack(&xq);
    let sbank: Vec<(SignMatrix, Vec<i8>)> =
        qbank.iter().map(|(h, hb)| (SignMatrix::pack_rows(h, M, N), hb.clone())).collect();

    // parity gate: the packed path must reproduce the i8 kernels exactly
    let mut qbeta = vec![0i8; M * N];
    let mut qeta = vec![0i8; M];
    q_precompute(&q, SIGN_FMT, &xq, &mut qbeta, &mut qeta);
    let mut want = vec![0i8; VOTERS * M];
    q_dm_layer_banked(&q, SIGN_FMT, &qbeta, &qeta, &qbank, block_rows, true, &mut want);
    let mut sbeta = SignMatrix::zeroed(M, N);
    let mut seta = vec![0i8; M];
    sign_precompute(&sl, &xs, &mut sbeta, &mut seta);
    let mut got = vec![0i8; VOTERS * M];
    sign_dm_layer(&sl, &sbeta, &seta, &sbank, true, &mut got);
    assert_eq!(got, want, "packed sign sweep must match the i8 kernels exactly");

    let mut ys = vec![0i8; VOTERS * M];
    let m_i8 = bench_for("i8 fixed  precompute+sweep", budget, || {
        q_precompute(&q, SIGN_FMT, &xq, &mut qbeta, &mut qeta);
        q_dm_layer_banked(&q, SIGN_FMT, &qbeta, &qeta, &qbank, block_rows, true, &mut ys);
        std::hint::black_box(&mut ys);
    });
    let m_sign = bench_for("packed ±1 precompute+sweep", budget, || {
        sign_precompute(&sl, &xs, &mut sbeta, &mut seta);
        sign_dm_layer(&sl, &sbeta, &seta, &sbank, true, &mut ys);
        std::hint::black_box(&mut ys);
    });
    let sign_speedup = m_i8.mean.as_secs_f64() / m_sign.mean.as_secs_f64();
    println!(
        "  packed sign: i8 {:>8.3} ms | packed {:>8.3} ms  ({sign_speedup:4.2}x)\n",
        m_i8.mean_ms(),
        m_sign.mean_ms()
    );

    let rendered: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"density\": {}, \"nnz\": {}, \"dense_ms\": {:.4}, \"sparse_ms\": {:.4}, \
                 \"speedup\": {:.3}}}",
                r.density, r.nnz, r.dense_ms, r.sparse_ms, r.speedup
            )
        })
        .collect();
    common::emit_bench_json(
        "sparsity",
        &common::json_doc(
            "sparsity",
            &[
                ("isa", format!("\"{}\"", simd::isa_label())),
                ("m", M.to_string()),
                ("n", N.to_string()),
                ("voters", VOTERS.to_string()),
                ("crossover_density", format!("{crossover}")),
                ("packed_sign_speedup", format!("{sign_speedup:.3}")),
            ],
            &rendered,
        ),
    );

    let best_low_density =
        rows.iter().filter(|r| r.density <= 0.30).map(|r| r.speedup).fold(0.0f64, f64::max);
    assert!(
        best_low_density >= 1.5,
        "acceptance: sparse must be >= 1.5x dense somewhere at density <= 0.30, \
         best measured {best_low_density:.2}x"
    );
    println!("OK: >= 1.5x over dense at >= 70% activation sparsity");
    assert!(
        sign_speedup >= 2.0,
        "acceptance: packed ±1 backend must be >= 2x the i8 kernels, measured \
         {sign_speedup:.2}x"
    );
    println!("OK: >= 2x over the i8 fixed-point kernels on the packed ±1 path");
}
