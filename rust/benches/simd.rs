//! Bench: runtime-dispatched SIMD vs forced-scalar kernel core on the
//! fused f32 DM layer sweep.
//!
//! Sweeps the paper's MNIST-MLP layer shapes plus tall/skinny edge
//! shapes.  For every shape the two paths are first asserted
//! **bit-identical** (the lane-stable reduction contract), then timed
//! single-threaded over the same α-blocked, micro-kernel-tiled sweep —
//! so the measured gap is pure ISA, not schedule.
//!
//! Acceptance shape: when a vector ISA is available at runtime, the
//! dispatched path is ≥ 2× the forced-scalar path on the f32 DM layer
//! for every shape with N ≥ 256.  (On scalar-only hardware both rungs
//! run the same code and the check is skipped.)
//!
//! Emits `BENCH_simd.json` at the repo root (shared `common` emitter).

mod common;

use std::time::Duration;

use bayesdm::dataset::LayerPosterior;
use bayesdm::grng::uniform::{UniformSource, XorShift128Plus};
use bayesdm::nn::kernels::dm_layer_blocked;
use bayesdm::nn::linear::precompute;
use bayesdm::nn::plan::TileGeometry;
use bayesdm::nn::simd::{self, Isa};
use bayesdm::opcount::OpCounter;
use bayesdm::util::bench::{bench_for, header};

const VOTERS: usize = 8;

struct Shape {
    name: &'static str,
    m: usize,
    n: usize,
}

const SHAPES: [Shape; 7] = [
    Shape { name: "mnist_l0", m: 200, n: 784 },
    Shape { name: "mnist_l1", m: 200, n: 200 },
    Shape { name: "mnist_l2", m: 10, n: 200 },
    Shape { name: "square_256", m: 256, n: 256 },
    Shape { name: "tall_skinny", m: 512, n: 8 },
    Shape { name: "short_wide", m: 8, n: 512 },
    Shape { name: "wide_4096", m: 64, n: 4096 },
];

struct Row {
    shape: &'static str,
    m: usize,
    n: usize,
    scalar_ms: f64,
    simd_ms: f64,
    speedup: f64,
}

fn to_json(isa: &str, rows: &[Row]) -> String {
    let fields = [("isa", format!("\"{isa}\"")), ("voters", VOTERS.to_string())];
    let rendered: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"shape\": \"{}\", \"m\": {}, \"n\": {}, \"scalar_ms\": {:.4}, \
                 \"simd_ms\": {:.4}, \"speedup\": {:.3}}}",
                r.shape, r.m, r.n, r.scalar_ms, r.simd_ms, r.speedup
            )
        })
        .collect();
    common::json_doc("simd", &fields, &rendered)
}

fn layer(m: usize, n: usize, seed: u64) -> LayerPosterior {
    let mut r = XorShift128Plus::new(seed);
    LayerPosterior {
        m,
        n,
        mu: (0..m * n).map(|_| r.next_f32() - 0.5).collect(),
        sigma: (0..m * n).map(|_| 0.01 + 0.05 * r.next_f32()).collect(),
        mu_b: (0..m).map(|_| r.next_f32() - 0.5).collect(),
        sigma_b: (0..m).map(|_| 0.01 + 0.05 * r.next_f32()).collect(),
    }
}

fn main() {
    header("SIMD — dispatched vector kernels vs forced-scalar, f32 DM layer");
    let vector_isa = simd::detect();
    println!(
        "detected: {}  (dispatch cached as: {})\n",
        vector_isa.name(),
        simd::isa_label()
    );

    let budget = Duration::from_millis(300);
    let mut rows: Vec<Row> = Vec::new();

    for shape in &SHAPES {
        let (m, n) = (shape.m, shape.n);
        let l = layer(m, n, 0x51D0 + m as u64);
        let mut r = XorShift128Plus::new(7);
        let x: Vec<f32> = (0..n).map(|_| r.next_f32()).collect();
        let bank: Vec<(Vec<f32>, Vec<f32>)> = (0..VOTERS)
            .map(|_| {
                (
                    (0..m * n).map(|_| r.next_f32() * 2.0 - 1.0).collect(),
                    (0..m).map(|_| r.next_f32() * 2.0 - 1.0).collect(),
                )
            })
            .collect();
        let mut ops = OpCounter::default();
        let mut beta = vec![0.0f32; m * n];
        let mut eta = vec![0.0f32; m];
        precompute(&l, &x, &mut beta, &mut eta, &mut ops);
        let block_rows = m.min(64); // one resident α block of ≤ 64 rows
        let tiles = TileGeometry::default();

        let sweep = |ys: &mut [f32]| {
            let mut ops = OpCounter::default();
            dm_layer_blocked(&l, &beta, &eta, &bank, block_rows, tiles, true, ys, &mut ops);
        };

        // parity gate before timing: both paths must agree bit-for-bit
        let mut want = vec![0.0f32; VOTERS * m];
        simd::set_active(Isa::Scalar);
        sweep(&mut want);
        let mut got = vec![0.0f32; VOTERS * m];
        simd::set_active(vector_isa);
        sweep(&mut got);
        assert_eq!(got, want, "{}: SIMD and forced-scalar logits must match", shape.name);

        simd::set_active(Isa::Scalar);
        let mut ys = vec![0.0f32; VOTERS * m];
        let m_scalar = bench_for(&format!("scalar {:<12} {m}x{n}", shape.name), budget, || {
            sweep(&mut ys);
            std::hint::black_box(&mut ys);
        });
        simd::set_active(vector_isa);
        let m_simd = bench_for(
            &format!("{:<6} {:<12} {m}x{n}", vector_isa.name(), shape.name),
            budget,
            || {
                sweep(&mut ys);
                std::hint::black_box(&mut ys);
            },
        );
        let speedup = m_scalar.mean.as_secs_f64() / m_simd.mean.as_secs_f64();
        println!(
            "  {:<12} {m:>4}x{n:<4}  scalar {:>8.3} ms | {} {:>8.3} ms  ({speedup:4.2}x)\n",
            shape.name,
            m_scalar.mean_ms(),
            vector_isa.name(),
            m_simd.mean_ms()
        );
        rows.push(Row {
            shape: shape.name,
            m,
            n,
            scalar_ms: m_scalar.mean_ms(),
            simd_ms: m_simd.mean_ms(),
            speedup,
        });
    }

    common::emit_bench_json("simd", &to_json(vector_isa.name(), &rows));

    if vector_isa == Isa::Scalar {
        println!("(no vector ISA at runtime: speedup acceptance check skipped)");
        return;
    }
    for r in &rows {
        if r.n >= 256 {
            assert!(
                r.speedup >= 2.0,
                "acceptance: {} ({}x{}) must run ≥2x over forced scalar, got {:.2}x",
                r.shape,
                r.m,
                r.n,
                r.speedup
            );
        }
    }
    println!("OK: >=2x over forced scalar on every f32 DM shape with N >= 256");
}
