//! Bench: batched engine throughput — serial vs single-thread-batched vs
//! pooled inputs/sec for all three methods.
//!
//! Three rungs per (method, batch size):
//!
//! * `serial`     — the seed repo's shape: one input at a time, each
//!   paying its own Θ/uncertainty sampling (`BnnModel::evaluate`).
//! * `engine w=1` — batched with shared per-batch banks on one thread:
//!   isolates the memoization win (sampling paid once per batch).
//! * `engine w=N` — the full pooled engine: memoization + one scoped
//!   worker per core.
//!
//! Acceptance shape (checked when ≥ 2 cores are available): the pooled
//! engine beats serial inputs/sec on DM-BNN for every batch ≥ 16.
//!
//! Emits `BENCH_throughput.json` at the repo root (shared `common`
//! emitter) — the machine-readable mirror of the printed table.

mod common;

use std::time::Duration;

use bayesdm::coordinator::engine::default_workers;
use bayesdm::dataset::{SynthSpec, Synthesizer};
use bayesdm::grng::default_grng;
use bayesdm::nn::batch::evaluate_batch;
use bayesdm::nn::bnn::{BnnModel, Method};
use bayesdm::util::bench::{bench_for, header, Measurement};
use bayesdm::MNIST_ARCH;

fn inputs_per_sec(batch: usize, m: &Measurement) -> f64 {
    batch as f64 / m.mean.as_secs_f64()
}

struct Row {
    method: &'static str,
    case: &'static str,
    batch: usize,
    inputs_per_sec: f64,
    mean_ms: f64,
}

fn to_json(pool: usize, rows: &[Row]) -> String {
    let fields = [
        ("workers", pool.to_string()),
        ("arch", format!("[{}]", MNIST_ARCH.map(|d| d.to_string()).join(","))),
    ];
    let rendered: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"method\": \"{}\", \"case\": \"{}\", \"batch\": {}, \
                 \"inputs_per_sec\": {:.2}, \"mean_ms\": {:.4}}}",
                r.method, r.case, r.batch, r.inputs_per_sec, r.mean_ms
            )
        })
        .collect();
    common::json_doc("throughput", &fields, &rendered)
}

fn main() {
    header("Throughput — batched multi-threaded engine vs serial");
    let pool = default_workers();
    println!("worker pool: {pool} threads  (arch {MNIST_ARCH:?})\n");

    let model = BnnModel::synthetic(&MNIST_ARCH, 0x7777);
    let data = Synthesizer::new(SynthSpec::mnist()).dataset(32);
    let all: Vec<Vec<f32>> = (0..data.len()).map(|i| data.image(i).to_vec()).collect();

    let methods = [
        ("standard T=8", "standard_t8", Method::Standard { t: 8 }),
        ("hybrid   T=8", "hybrid_t8", Method::Hybrid { t: 8 }),
        ("dm 2x2x2 (8v)", "dm_2x2x2", Method::DmBnn { schedule: vec![2, 2, 2] }),
    ];
    let budget = Duration::from_millis(400);
    let mut dm_pooled_vs_serial: Vec<(usize, f64, f64)> = Vec::new();
    let mut rows: Vec<Row> = Vec::new();

    for (name, id, method) in &methods {
        println!("{name}:");
        for &bs in &[1usize, 8, 16, 32] {
            let xs = &all[..bs];
            let m_serial = bench_for(&format!("serial       b={bs}"), budget, || {
                for x in xs {
                    let mut g = default_grng(42);
                    std::hint::black_box(model.evaluate(x, method, &mut g));
                }
            });
            let m_one = bench_for(&format!("engine w=1   b={bs}"), budget, || {
                std::hint::black_box(evaluate_batch(&model, xs, method, 42, 1));
            });
            let m_pool = bench_for(&format!("engine w={pool}   b={bs}"), budget, || {
                std::hint::black_box(evaluate_batch(&model, xs, method, 42, pool));
            });
            let s = inputs_per_sec(bs, &m_serial);
            let o = inputs_per_sec(bs, &m_one);
            let p = inputs_per_sec(bs, &m_pool);
            for (case, ips, meas) in [
                ("serial", s, &m_serial),
                ("engine_w1", o, &m_one),
                ("engine_pool", p, &m_pool),
            ] {
                rows.push(Row {
                    method: *id,
                    case,
                    batch: bs,
                    inputs_per_sec: ips,
                    mean_ms: meas.mean_ms(),
                });
            }
            println!(
                "  b={bs:<3} serial {s:>9.1} in/s | engine w=1 {o:>9.1} in/s \
                 ({:4.2}x) | engine w={pool} {p:>9.1} in/s ({:4.2}x)",
                o / s,
                p / s
            );
            if matches!(method, Method::DmBnn { .. }) {
                dm_pooled_vs_serial.push((bs, s, p));
            }
        }
        println!();
    }

    common::emit_bench_json("throughput", &to_json(pool, &rows));

    if pool >= 2 {
        for &(bs, serial, pooled) in &dm_pooled_vs_serial {
            if bs >= 16 {
                assert!(
                    pooled > serial,
                    "pooled engine must beat serial on DM-BNN at batch {bs}: \
                     {pooled:.1} vs {serial:.1} inputs/sec"
                );
            }
        }
        println!("OK: pooled engine beats serial on DM-BNN for every batch >= 16");
    } else {
        println!("(single core: pooled-vs-serial acceptance check skipped)");
    }
}
