//! Bench: tail latency under synthetic load — deadline-aware batching
//! and admission control on the serving router.
//!
//! Two scenarios over the bursty, Zipf-skewed traffic generator
//! (`util::traffic`), both on the self-contained synthetic model:
//!
//! * `loaded` — paced arrivals (bursts included) against a deadline-on
//!   server with headroom: reports the served p50/p99/p999 split, and
//!   requires that nothing was shed or expired (the deadline machinery
//!   must be invisible when capacity suffices);
//! * `overload` — an unpaced burst into a tiny admission queue behind a
//!   single slow dispatch lane: requires explicit `Overloaded` sheds
//!   (no blocking, no silent drops) while the p99 of requests that WERE
//!   admitted and served stays bounded — queue wait is capped by the
//!   deadline, so tail latency cannot grow with offered load.
//!
//! Emits `BENCH_latency.json` at the repo root (shared `common` emitter).

mod common;

use std::sync::Arc;
use std::time::Duration;

use bayesdm::coordinator::engine::default_workers;
use bayesdm::coordinator::plan::InferenceMethod;
use bayesdm::coordinator::{serve_engine, Engine, EngineConfig, ServerConfig, ServerHandle};
use bayesdm::dataset::{SynthSpec, Synthesizer};
use bayesdm::nn::bnn::BnnModel;
use bayesdm::serve::ServeError;
use bayesdm::util::bench::header;
use bayesdm::util::traffic::{TrafficGen, TrafficSpec};
use bayesdm::MNIST_ARCH;

const CATALOG: usize = 32;

fn engine() -> Arc<Engine> {
    let model = BnnModel::synthetic(&MNIST_ARCH, 0x1A7E);
    Arc::new(Engine::new(
        model,
        EngineConfig { workers: default_workers(), seed: 0x1A7E, ..EngineConfig::default() },
    ))
}

fn catalog_images() -> Vec<Vec<f32>> {
    let data = Synthesizer::new(SynthSpec::mnist()).dataset(CATALOG);
    (0..data.len()).map(|i| data.image(i).to_vec()).collect()
}

struct Outcome {
    served: usize,
    shed: u64,
    expired: u64,
    errors: u64,
    p50_us: u64,
    p99_us: u64,
    p999_us: u64,
}

/// Drive `n` arrivals through `handle`; `paced` sleeps each generator
/// gap, unpaced submits the whole stream as one burst.
fn drive(handle: &ServerHandle, images: &[Vec<f32>], n: usize, paced: bool) -> Outcome {
    let method = InferenceMethod::DmBnn { schedule: vec![2, 2, 2], alpha: 1.0 };
    let spec = TrafficSpec {
        base_rate_hz: 200.0,
        burst_factor: 8.0,
        catalog: CATALOG,
        ..TrafficSpec::default()
    };
    let mut gen = TrafficGen::new(spec, 0xBEA7);
    let mut pending = Vec::with_capacity(n);
    let mut served = 0usize;
    for _ in 0..n {
        let a = gen.next_arrival();
        if paced {
            std::thread::sleep(a.gap.min(Duration::from_millis(20)));
        }
        match handle.classify(images[a.item % images.len()].clone(), method.clone()) {
            Ok(p) => pending.push(p),
            Err(ServeError::Overloaded) => {} // counted by the server as shed
            Err(e) => panic!("submit: {e}"),
        }
    }
    for p in pending {
        if p.wait().is_ok() {
            served += 1;
        }
    }
    let s = handle.metrics.summary();
    Outcome {
        served,
        shed: s.shed,
        expired: s.expired,
        errors: s.errors,
        p50_us: s.p50_us.unwrap_or(0),
        p99_us: s.p99_us.unwrap_or(0),
        p999_us: s.p999_us.unwrap_or(0),
    }
}

fn row(scenario: &str, n: usize, deadline_ms: u64, o: &Outcome) -> String {
    format!(
        "{{\"scenario\": \"{scenario}\", \"requests\": {n}, \"deadline_ms\": {deadline_ms}, \
         \"served\": {}, \"shed\": {}, \"expired\": {}, \"p50_us\": {}, \"p99_us\": {}, \
         \"p999_us\": {}}}",
        o.served, o.shed, o.expired, o.p50_us, o.p99_us, o.p999_us
    )
}

fn main() {
    header("Latency — deadline-aware batching & admission control under load");
    println!("engine pool: {} threads, catalog {CATALOG} (Zipf), dm 2x2x2\n", default_workers());
    let images = catalog_images();
    let mut rows = Vec::new();

    // --- loaded: paced bursty stream, ample queue, deadline as headroom.
    let n = 400;
    let deadline = Duration::from_millis(500);
    let handle = serve_engine(
        engine(),
        ServerConfig {
            max_batch: 8,
            workers: 1,
            deadline: Some(deadline),
            ..ServerConfig::default()
        },
    );
    let o = drive(&handle, &images, n, true);
    handle.shutdown();
    assert_eq!(o.served, n, "loaded: every paced request must be served");
    assert_eq!((o.shed, o.expired, o.errors), (0, 0, 0), "loaded: no shedding with headroom");
    println!(
        "loaded    {n} reqs  p50 {}µs  p99 {}µs  p999 {}µs  (shed 0, expired 0)",
        o.p50_us, o.p99_us, o.p999_us
    );
    rows.push(row("loaded", n, deadline.as_millis() as u64, &o));

    // --- overload: unpaced burst into a tiny queue, one slow lane.
    let n = 256;
    let deadline = Duration::from_millis(250);
    let handle = serve_engine(
        engine(),
        ServerConfig {
            max_batch: 4,
            workers: 1,
            queue_depth: 4,
            deadline: Some(deadline),
            ..ServerConfig::default()
        },
    );
    let o = drive(&handle, &images, n, false);
    handle.shutdown();
    assert!(o.shed > 0, "overload: a full queue must shed explicitly");
    assert_eq!(o.shed as usize + o.served + o.expired as usize, n, "every request accounted");
    let bound_us = 2 * deadline.as_micros() as u64;
    assert!(
        o.p99_us <= bound_us,
        "overload: admitted p99 {}µs must stay within 2x the {}ms deadline",
        o.p99_us,
        deadline.as_millis()
    );
    println!(
        "overload  {n} reqs  served {}  shed {}  expired {}  p99 {}µs (bound {bound_us}µs)",
        o.served, o.shed, o.expired, o.p99_us
    );
    rows.push(row("overload", n, deadline.as_millis() as u64, &o));

    let json = common::json_doc(
        "latency",
        &[("catalog", CATALOG.to_string()), ("method", "\"dm_2x2x2\"".to_string())],
        &rows,
    );
    common::emit_bench_json("latency", &json);
    println!("\nacceptance: overload sheds explicitly; admitted p99 bounded by the deadline");
}
