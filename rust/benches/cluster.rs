//! Bench: cluster serving — shard-count × duplicate-rate × memoization
//! sweep over a synthetic request stream, through `ClusterRouter`.
//!
//! Two acceptance shapes (guarded by the host's core count where the win
//! *is* the cores):
//!
//! * at 0% duplicates, 4 shards sustain ≥ 1.5× the aggregate throughput
//!   of 1 shard (shard workers run requests concurrently; asserted when
//!   the host has ≥ 4 cores);
//! * at 90% duplicates with response memoization on, the warm 4-shard
//!   deployment sustains ≥ 3× the memo-less 1-shard baseline (memo hits
//!   skip the entire voter sweep, so this does not depend on core count).
//!
//! Every measured configuration is asserted bit-identical to the 1-shard
//! memo-less baseline first, then timed.  Emits `BENCH_cluster.json`.

mod common;

use std::time::Duration;

use bayesdm::cluster::{ClusterRouter, MemoConfig};
use bayesdm::coordinator::{CacheConfig, EngineConfig, SeedSchedule};
use bayesdm::grng::uniform::{UniformSource, XorShift128Plus};
use bayesdm::nn::bnn::{BnnModel, Method};
use bayesdm::util::bench::{bench_for, header, Measurement};
use bayesdm::MNIST_ARCH;

const POOL: usize = 4; // hot images
const REQS: usize = 64; // requests per iteration
const SEED: u64 = 0xC1057E8;

struct Stream {
    pool: Vec<Vec<f32>>,
    rng: XorShift128Plus,
    rate_pct: usize,
}

impl Stream {
    fn new(rate_pct: usize) -> Self {
        let mut rng = XorShift128Plus::new(0xF00D);
        let dim = MNIST_ARCH[0];
        let pool = (0..POOL)
            .map(|_| (0..dim).map(|_| rng.next_f32()).collect())
            .collect();
        Self { pool, rng, rate_pct }
    }

    /// Next request set: `rate_pct`% of slots cycle the hot pool, the
    /// rest are fresh never-seen images (honest churn against the memo).
    fn next_requests(&mut self) -> Vec<Vec<f32>> {
        let dim = MNIST_ARCH[0];
        (0..REQS)
            .map(|slot| {
                if slot * 100 < self.rate_pct * REQS {
                    self.pool[slot % POOL].clone()
                } else {
                    (0..dim).map(|_| self.rng.next_f32()).collect()
                }
            })
            .collect()
    }
}

fn router(shards: usize, memo: MemoConfig) -> ClusterRouter {
    ClusterRouter::new(
        BnnModel::synthetic(&MNIST_ARCH, 0x7A57E),
        EngineConfig {
            workers: 1,
            seed: SEED,
            cache: CacheConfig::disabled(),
            seed_schedule: SeedSchedule::ContentHash,
            alpha: 1.0,
            shards,
            memo,
            snapshot: None,
            sparse_threshold: None,
        },
    )
}

fn run_stream(r: &ClusterRouter, method: &Method, stream: &mut Stream) {
    let xs = stream.next_requests();
    std::hint::black_box(r.evaluate(&xs, method).expect("cluster evaluate"));
}

fn inputs_per_sec(m: &Measurement) -> f64 {
    REQS as f64 / m.mean.as_secs_f64()
}

fn main() {
    header("Cluster serving — shard-count × duplicate-rate × memo sweep");
    let method = Method::DmBnn { schedule: vec![2, 2, 2] };
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    println!(
        "arch {MNIST_ARCH:?}, dm 2x2x2, {REQS} requests/iter, hot pool {POOL}, {cores} cores\n"
    );

    // Parity spot-check before timing anything: shard count and memo are
    // invisible in the results.
    {
        let base = router(1, MemoConfig::disabled());
        let wide = router(4, MemoConfig::with_mb(32));
        let mut sa = Stream::new(90);
        let mut sb = Stream::new(90);
        for round in 0..2 {
            let xs = sa.next_requests();
            let ys = sb.next_requests();
            let a = base.evaluate(&xs, &method).unwrap();
            let b = wide.evaluate(&ys, &method).unwrap();
            assert_eq!(a.logits, b.logits, "round {round}: sharding/memo changed results");
            assert_eq!(a.ops.muls, b.ops.muls, "round {round}: logical muls moved");
            assert_eq!(a.ops.adds, b.ops.adds, "round {round}: logical adds moved");
        }
        println!("parity: 4-shard memoized logits and logical op counts bit-identical\n");
    }

    let budget = Duration::from_millis(600);
    let mut rows: Vec<String> = Vec::new();
    let row = |shards: usize, memo_mb: usize, rate: usize, ips: f64, speedup: f64| {
        format!(
            "{{\"shards\": {shards}, \"memo_mb\": {memo_mb}, \"duplicate_rate_pct\": {rate}, \
             \"inputs_per_sec\": {ips:.1}, \"speedup_vs_1shard\": {speedup:.3}}}"
        )
    };

    // Leg 1 — 0% duplicates: the win is shard parallelism.
    println!("duplicate rate 0% (shard scaling):");
    let r1 = router(1, MemoConfig::disabled());
    let mut s = Stream::new(0);
    let m1 = bench_for("1 shard          rate=0%", budget, || run_stream(&r1, &method, &mut s));
    let base_ips = inputs_per_sec(&m1);
    rows.push(row(1, 0, 0, base_ips, 1.0));
    let mut scale_speedup = None;
    for shards in [2usize, 4] {
        let r = router(shards, MemoConfig::disabled());
        let mut s = Stream::new(0);
        let m = bench_for(&format!("{shards} shards         rate=0%"), budget, || {
            run_stream(&r, &method, &mut s)
        });
        let ips = inputs_per_sec(&m);
        let speedup = ips / base_ips;
        println!(
            "  {shards} shards: {ips:>9.1} in/s | 1 shard {base_ips:>9.1} in/s | {speedup:>5.2}x"
        );
        rows.push(row(shards, 0, 0, ips, speedup));
        if shards == 4 {
            scale_speedup = Some(speedup);
        }
    }
    println!();

    // Leg 2 — 90% duplicates, memo on: the win is the skipped sweep.
    println!("duplicate rate 90% (memoization):");
    let r1 = router(1, MemoConfig::disabled());
    let mut s = Stream::new(90);
    let m1 = bench_for("1 shard  no memo rate=90%", budget, || run_stream(&r1, &method, &mut s));
    let dup_base_ips = inputs_per_sec(&m1);
    rows.push(row(1, 0, 90, dup_base_ips, 1.0));
    let memo_mb = 32usize;
    let rm = router(4, MemoConfig::with_mb(memo_mb));
    let mut s = Stream::new(90);
    run_stream(&rm, &method, &mut s); // warm the hot-pool responses
    let mm = bench_for("4 shards 32 MiB  rate=90%", budget, || run_stream(&rm, &method, &mut s));
    let memo_ips = inputs_per_sec(&mm);
    let memo_speedup = memo_ips / dup_base_ips;
    let stats = rm.metrics_summary().memo.expect("memo enabled");
    println!(
        "  4 shards + memo: {memo_ips:>9.1} in/s | baseline {dup_base_ips:>9.1} in/s | \
         {memo_speedup:>5.2}x | memo[{stats}]"
    );
    rows.push(row(4, memo_mb, 90, memo_ips, memo_speedup));
    println!();

    let scale_speedup = scale_speedup.expect("4-shard leg measured");
    common::emit_bench_json(
        "cluster",
        &common::json_doc(
            "cluster",
            &[
                ("requests_per_iter", REQS.to_string()),
                ("cores", cores.to_string()),
                ("shard_speedup_4x_rate0", format!("{scale_speedup:.3}")),
                ("memo_speedup_4x_rate90", format!("{memo_speedup:.3}")),
            ],
            &rows,
        ),
    );

    if cores >= 4 {
        assert!(
            scale_speedup >= 1.5,
            "acceptance: 4 shards must be >= 1.5x 1 shard at 0% duplicates on a \
             {cores}-core host, measured {scale_speedup:.2}x"
        );
        println!("OK: >= 1.5x aggregate throughput for 4 shards at 0% duplicates");
    } else {
        println!(
            "note: {cores} cores < 4 — shard-scaling assertion skipped \
             (measured {scale_speedup:.2}x)"
        );
    }
    assert!(
        memo_speedup >= 3.0,
        "acceptance: warm memo on the 90%-duplicate stream must be >= 3x the \
         memo-less 1-shard baseline, measured {memo_speedup:.2}x"
    );
    println!("OK: >= 3x on the 90%-duplicate stream with memoization on");
}
