//! Shared helpers for the bench targets (each `harness = false` bench is
//! its own binary; this module is compiled into each via `mod common;`).
//!
//! The one job here is consistent artifact placement: every bench emits a
//! machine-readable `BENCH_<name>.json` **at the repository root**, so
//! the perf trajectory always finds them in one canonical place no
//! matter whether the bench was invoked from the root, from `rust/`, or
//! from a CI working directory.
#![allow(dead_code)]

use std::path::{Path, PathBuf};

/// Locate the repository root: the nearest ancestor of the current
/// working directory containing `.git` or the `CHANGES.md` marker.
/// Falls back to the working directory itself (bench output is still
/// written, just not hoisted).
pub fn repo_root() -> PathBuf {
    let cwd = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    let mut dir: &Path = &cwd;
    loop {
        if dir.join(".git").exists() || dir.join("CHANGES.md").exists() {
            return dir.to_path_buf();
        }
        match dir.parent() {
            Some(parent) => dir = parent,
            None => return cwd.clone(),
        }
    }
}

/// Assemble a `BENCH_*.json` document: `fields` are extra top-level
/// `"key": value` pairs (values pre-rendered as JSON — no serde
/// offline; all bench strings are identifier-safe, so no escaping),
/// `rows` are pre-rendered row objects placed under `"rows"`.  Keeps
/// the emitters' scaffolding (indentation, trailing commas) in one
/// place; only the per-bench row shape lives with each bench.
pub fn json_doc(bench: &str, fields: &[(&str, String)], rows: &[String]) -> String {
    let mut s = format!("{{\n  \"bench\": \"{bench}\"");
    for (k, v) in fields {
        s.push_str(&format!(",\n  \"{k}\": {v}"));
    }
    s.push_str(",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!("    {r}{}\n", if i + 1 == rows.len() { "" } else { "," }));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Write `BENCH_<name>.json` at the repo root and report where it went.
pub fn emit_bench_json(name: &str, json: &str) -> PathBuf {
    let path = repo_root().join(format!("BENCH_{name}.json"));
    std::fs::write(&path, json).unwrap_or_else(|e| panic!("write {}: {e}", path.display()));
    println!("wrote {}", path.display());
    path
}
