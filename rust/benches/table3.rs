//! Bench: Table III — single-layer computation cost, ±DM.
//!
//! Regenerates the paper's analytic table for the paper's layer shape
//! (M=200, N=784) across T, verifies the instrumented dataflows match
//! the closed forms exactly, and times the two single-layer dataflows to
//! show the measured speedup tracks the 2-cycle-MUL model's prediction.
//!
//! Emits `BENCH_table3.json` at the repo root (shared `common` emitter).

mod common;

use bayesdm::dataset::LayerPosterior;
use bayesdm::grng::uniform::{UniformSource, XorShift128Plus};
use bayesdm::nn::linear;
use bayesdm::opcount::model::{dm_mul_ratio, table3_dm, table3_standard};
use bayesdm::opcount::report::render_table3;
use bayesdm::opcount::OpCounter;
use bayesdm::util::bench::{bench, header};

fn random_layer(m: usize, n: usize, seed: u64) -> LayerPosterior {
    let mut r = XorShift128Plus::new(seed);
    LayerPosterior {
        m,
        n,
        mu: (0..m * n).map(|_| r.next_f32() - 0.5).collect(),
        sigma: (0..m * n).map(|_| 0.01 + 0.1 * r.next_f32()).collect(),
        mu_b: (0..m).map(|_| r.next_f32() - 0.5).collect(),
        sigma_b: (0..m).map(|_| 0.01 + 0.1 * r.next_f32()).collect(),
    }
}

fn main() {
    header("Table III — single-layer BNN computation cost");
    let (m, n) = (200usize, 784usize);

    // The analytic table at the paper's T plus the Eqn (3) asymptote.
    for t in [10u64, 100, 1000] {
        println!("{}", render_table3(m as u64, n as u64, t));
    }
    println!("Eqn (3) ratio vs T:");
    for t in [3u64, 10, 100, 1000, 100000] {
        println!("  T={t:>7}: MN(T+2)/2MNT = {:.4}", dm_mul_ratio(t));
    }

    // Measured single-layer wall-clock: standard vs DM for T voters.
    let layer = random_layer(m, n, 1);
    let t = 100usize;
    let mut r = XorShift128Plus::new(2);
    let x: Vec<f32> = (0..n).map(|_| r.next_f32()).collect();
    let hs: Vec<Vec<f32>> =
        (0..t).map(|_| (0..m * n).map(|_| r.next_f32() - 0.5).collect()).collect();
    let hbs: Vec<Vec<f32>> =
        (0..t).map(|_| (0..m).map(|_| r.next_f32() - 0.5).collect()).collect();

    println!("\nmeasured single-layer dataflow (M={m}, N={n}, T={t}):");
    let mut y = vec![0.0f32; m];
    let m_std = bench("standard: T x (scale-loc + matvec)", 1, 10, || {
        let mut ops = OpCounter::default();
        for k in 0..t {
            linear::standard_voter(&layer, &x, &hs[k], &hbs[k], false, &mut y, &mut ops);
        }
        std::hint::black_box(&y);
    });
    println!("  {m_std}");

    let mut beta = vec![0.0f32; m * n];
    let mut eta = vec![0.0f32; m];
    let m_dm = bench("dm: precompute + T x linewise", 1, 10, || {
        let mut ops = OpCounter::default();
        linear::precompute(&layer, &x, &mut beta, &mut eta, &mut ops);
        for k in 0..t {
            linear::dm_voter(&layer, &beta, &eta, &hs[k], &hbs[k], 0, false, &mut y, &mut ops);
        }
        std::hint::black_box(&y);
    });
    println!("  {m_dm}");
    let speedup = m_std.mean.as_secs_f64() / m_dm.mean.as_secs_f64();
    let predicted = table3_standard(m as u64, n as u64, t as u64).weighted_cycles() as f64
        / table3_dm(m as u64, n as u64, t as u64).weighted_cycles() as f64;
    println!(
        "\n  measured speedup {speedup:.2}x (paper's weighted-cycle model predicts {predicted:.2}x)"
    );

    let rows: Vec<String> = [3u64, 10, 100, 1000, 100000]
        .iter()
        .map(|&t| format!("{{\"t\": {t}, \"dm_mul_ratio\": {:.6}}}", dm_mul_ratio(t)))
        .collect();
    common::emit_bench_json(
        "table3",
        &common::json_doc(
            "table3",
            &[
                ("m", m.to_string()),
                ("n", n.to_string()),
                ("t", t.to_string()),
                ("measured_speedup", format!("{speedup:.3}")),
                ("predicted_speedup", format!("{predicted:.3}")),
            ],
            &rows,
        ),
    );
}
