//! Bench: Fig 6 — NN vs BNN accuracy under the shrink-ratio protocol.
//!
//! The training sweep itself is a compile-path job (`make fig6` →
//! `artifacts/fig6.json`; 20 model trainings).  This bench renders the
//! curves, asserts the paper's qualitative claims on them, and times the
//! rust-side pieces of the protocol (dataset synthesis + subset
//! selection).
//!
//! Emits `BENCH_fig6.json` at the repo root (shared `common` emitter).

mod common;

use bayesdm::dataset::{shrink_subset, SynthSpec, Synthesizer};
use bayesdm::util::bench::{bench, header};
use bayesdm::util::Json;

fn main() {
    header("Fig 6 — NN vs BNN accuracy vs shrink ratio");

    let mut rows: Vec<String> = Vec::new();
    let mut bnn_wins_small = 0usize;
    let mut total_small = 0usize;
    let have_artifacts = match std::fs::read_to_string("artifacts/fig6.json") {
        Ok(text) => {
            let v = Json::parse(&text).expect("fig6.json parse");
            for (ds, curve) in v.get("datasets").and_then(Json::as_obj).unwrap() {
                println!("dataset {ds}:");
                let nn = curve.get("nn").and_then(Json::as_obj).unwrap();
                let bnn = curve.get("bnn").and_then(Json::as_obj).unwrap();
                let mut ratios: Vec<usize> =
                    nn.keys().filter_map(|k| k.parse().ok()).collect();
                ratios.sort_unstable();
                for r in &ratios {
                    let a = nn[&r.to_string()].as_f64().unwrap_or(0.0);
                    let b = bnn[&r.to_string()].as_f64().unwrap_or(0.0);
                    println!(
                        "  ratio {r:>5}: NN {:6.2}%  BNN {:6.2}%  Δ {:+5.2}",
                        100.0 * a,
                        100.0 * b,
                        100.0 * (b - a)
                    );
                    rows.push(format!(
                        "{{\"dataset\": \"{ds}\", \"ratio\": {r}, \"nn\": {a:.4}, \
                         \"bnn\": {b:.4}}}"
                    ));
                    if *r >= 256 {
                        total_small += 1;
                        if b >= a {
                            bnn_wins_small += 1;
                        }
                    }
                }
            }
            println!(
                "\nBNN >= NN at large shrink ratios (>=256): {bnn_wins_small}/{total_small} \
                 (paper Fig 6: BNN wins as training data shrinks)"
            );
            true
        }
        Err(_) => {
            println!("fig6.json not built — run `make fig6` (trains 20 models)");
            false
        }
    };

    // Rust-side protocol costs.
    println!("\nprotocol micro-benchmarks:");
    let mut synth = Synthesizer::new(SynthSpec::mnist());
    let m_synth = bench("synthesize 1000 images", 1, 5, || {
        std::hint::black_box(synth.dataset(1000));
    });
    println!("  {m_synth}");
    let pool = Synthesizer::new(SynthSpec::mnist()).dataset(5000);
    let m_shrink = bench("shrink_subset ratio=256", 1, 20, || {
        std::hint::black_box(shrink_subset(&pool, 256, 60_000, 7));
    });
    println!("  {m_shrink}");

    common::emit_bench_json(
        "fig6",
        &common::json_doc(
            "fig6",
            &[
                ("have_artifacts", have_artifacts.to_string()),
                ("bnn_wins_large_ratio", bnn_wins_small.to_string()),
                ("total_large_ratio", total_small.to_string()),
                ("synthesize_1000_ms", format!("{:.4}", m_synth.mean_ms())),
                ("shrink_subset_ms", format!("{:.4}", m_shrink.mean_ms())),
            ],
            &rows,
        ),
    );
}
