//! Bench: Fig 6 — NN vs BNN accuracy under the shrink-ratio protocol.
//!
//! The training sweep itself is a compile-path job (`make fig6` →
//! `artifacts/fig6.json`; 20 model trainings).  This bench renders the
//! curves, asserts the paper's qualitative claims on them, and times the
//! rust-side pieces of the protocol (dataset synthesis + subset
//! selection).

use bayesdm::dataset::{shrink_subset, SynthSpec, Synthesizer};
use bayesdm::util::bench::{bench, header};
use bayesdm::util::Json;

fn main() {
    header("Fig 6 — NN vs BNN accuracy vs shrink ratio");

    match std::fs::read_to_string("artifacts/fig6.json") {
        Ok(text) => {
            let v = Json::parse(&text).expect("fig6.json parse");
            let mut bnn_wins_small = 0usize;
            let mut total_small = 0usize;
            for (ds, curve) in v.get("datasets").and_then(Json::as_obj).unwrap() {
                println!("dataset {ds}:");
                let nn = curve.get("nn").and_then(Json::as_obj).unwrap();
                let bnn = curve.get("bnn").and_then(Json::as_obj).unwrap();
                let mut ratios: Vec<usize> =
                    nn.keys().filter_map(|k| k.parse().ok()).collect();
                ratios.sort_unstable();
                for r in &ratios {
                    let a = nn[&r.to_string()].as_f64().unwrap_or(0.0);
                    let b = bnn[&r.to_string()].as_f64().unwrap_or(0.0);
                    println!(
                        "  ratio {r:>5}: NN {:6.2}%  BNN {:6.2}%  Δ {:+5.2}",
                        100.0 * a,
                        100.0 * b,
                        100.0 * (b - a)
                    );
                    if *r >= 256 {
                        total_small += 1;
                        if b >= a {
                            bnn_wins_small += 1;
                        }
                    }
                }
            }
            println!(
                "\nBNN >= NN at large shrink ratios (>=256): {bnn_wins_small}/{total_small} \
                 (paper Fig 6: BNN wins as training data shrinks)"
            );
        }
        Err(_) => println!("fig6.json not built — run `make fig6` (trains 20 models)"),
    }

    // Rust-side protocol costs.
    println!("\nprotocol micro-benchmarks:");
    let mut synth = Synthesizer::new(SynthSpec::mnist());
    let m = bench("synthesize 1000 images", 1, 5, || {
        std::hint::black_box(synth.dataset(1000));
    });
    println!("  {m}");
    let pool = Synthesizer::new(SynthSpec::mnist()).dataset(5000);
    let m = bench("shrink_subset ratio=256", 1, 20, || {
        std::hint::black_box(shrink_subset(&pool, 256, 60_000, 7));
    });
    println!("  {m}");
}
