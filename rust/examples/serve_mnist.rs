//! End-to-end serving driver (DESIGN.md §5 "E2E").
//!
//! Boots the router/batcher over the batched reference engine, replays
//! test-set images as classification requests for each of the paper's
//! three methods (DM at α = 1.0 and the memory-friendly α = 0.1), and
//! reports accuracy, throughput and latency percentiles.
//!
//! Runs with **zero artifacts** on the synthetic posterior/dataset; pass
//! a request count and it still just works.
//!
//! ```bash
//! cargo run --release --offline --example serve_mnist [-- <requests>]
//! ```

use std::sync::Arc;
use std::time::Instant;

use bayesdm::coordinator::plan::InferenceMethod;
use bayesdm::coordinator::{serve_engine, Engine, EngineConfig, ServerConfig};
use bayesdm::dataset::{load_images, load_weights, Dataset, SynthSpec, Synthesizer};
use bayesdm::nn::bnn::BnnModel;
use bayesdm::util::error::Result;
use bayesdm::MNIST_ARCH;

const ARTIFACTS: &str = "artifacts";

fn load() -> (BnnModel, Dataset) {
    let weights = load_weights(format!("{ARTIFACTS}/weights_mnist_bnn.bin"));
    let test = load_images(format!("{ARTIFACTS}/data_mnist_test.bin"));
    match (weights, test) {
        (Ok(w), Ok(t)) => (BnnModel::new(w), t),
        _ => (
            BnnModel::synthetic(&MNIST_ARCH, 0xE2E5),
            Synthesizer::new(SynthSpec::mnist()).dataset(256),
        ),
    }
}

fn main() -> Result<()> {
    let requests: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("requests must be a number"))
        .unwrap_or(100);

    println!("end-to-end serving driver: up to {requests} requests per method\n");
    println!(
        "{:<10} {:>9} {:>10} {:>10} {:>10} {:>8}",
        "method", "req/s", "p50 (ms)", "p99 (ms)", "voters", "accuracy"
    );

    for (label, alpha, method) in [
        ("standard", 1.0, InferenceMethod::Standard { t: 100 }),
        ("hybrid", 1.0, InferenceMethod::Hybrid { t: 100 }),
        ("dm a=1.0", 1.0, InferenceMethod::paper_dm(1.0)),
        ("dm a=0.1", 0.1, InferenceMethod::paper_dm(0.1)),
    ] {
        let (model, test) = load();
        let n = requests.min(test.len());
        let engine = Arc::new(Engine::new(
            model,
            EngineConfig { seed: 0xE2E, alpha, ..EngineConfig::default() },
        ));
        // One dispatch worker: the engine's scoped pool is the parallelism.
        let cfg = ServerConfig { max_batch: 8, workers: 1, ..ServerConfig::default() };
        let handle = serve_engine(engine, cfg);
        let t0 = Instant::now();
        let mut pending = Vec::with_capacity(n);
        for i in 0..n {
            pending.push((
                test.labels[i],
                handle
                    .classify(test.image(i).to_vec(), method.clone())
                    .map_err(bayesdm::util::error::Error::msg)?,
            ));
        }
        let mut correct = 0usize;
        let mut voters = 0usize;
        for (lbl, p) in pending {
            let r = p.wait().map_err(bayesdm::util::error::Error::msg)?;
            voters = r.voters;
            if r.class == lbl as usize {
                correct += 1;
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        let s = handle.metrics.summary();
        println!(
            "{:<10} {:>9.2} {:>10.1} {:>10.1} {:>10} {:>7.1}%",
            label,
            n as f64 / dt,
            s.p50_us.unwrap_or(0) as f64 / 1e3,
            s.p99_us.unwrap_or(0) as f64 / 1e3,
            voters,
            100.0 * correct as f64 / n as f64,
        );
        handle.shutdown();
    }
    println!("\n(paper Table V shape: DM ≈ 4× faster than standard at equal+ voters;");
    println!(" α changes the working set, never the logits)");
    Ok(())
}
