//! Small-data & uncertainty: the BNN behaviours Fig 6 and §V-A motivate.
//!
//! Three demonstrations, all artifact-free on the reference engine:
//!
//! 1. the shrink-ratio protocol (paper §V-A) on the native synthetic
//!    dataset — how many images survive each ratio;
//! 2. predictive entropy as an uncertainty signal: corrupting an input
//!    (occlusion / noise) must raise the BNN's entropy — the core reason
//!    to pay for Bayesian inference at the edge;
//! 3. the Fig 6 accuracy curves, rendered from `artifacts/fig6.json`
//!    when present (`make fig6`).
//!
//! ```bash
//! cargo run --release --offline --example small_data
//! ```

use bayesdm::coordinator::plan::InferenceMethod;
use bayesdm::coordinator::{vote, Engine, EngineConfig};
use bayesdm::dataset::{shrink_subset, SynthSpec, Synthesizer};
use bayesdm::grng::uniform::{UniformSource, XorShift128Plus};
use bayesdm::nn::bnn::BnnModel;
use bayesdm::util::error::Result;
use bayesdm::util::Json;
use bayesdm::MNIST_ARCH;

const ARTIFACTS: &str = "artifacts";

fn main() -> Result<()> {
    // --- 1. shrink-ratio protocol on the native generator ----------------
    println!("shrink-ratio protocol (nominal 60000 images, paper §V-A):");
    let mut synth = Synthesizer::new(SynthSpec::mnist());
    let pool = synth.dataset(3000);
    for ratio in [16usize, 64, 256, 1024] {
        let sub = shrink_subset(&pool, ratio, 60_000, 7);
        println!(
            "  ratio {ratio:>5} -> {:>4} images ({} per class)",
            sub.len(),
            sub.len() / 10
        );
    }

    // --- 2. uncertainty under corruption ---------------------------------
    let engine = Engine::new(
        BnnModel::synthetic(&MNIST_ARCH, 0x5EED),
        EngineConfig { seed: 0x5EED, ..EngineConfig::default() },
    );
    let method = InferenceMethod::Standard { t: 50 };
    let entropy_of = |x: Vec<f32>, seed: u64| -> (usize, f32) {
        let r = engine.evaluate_batch_seeded(&[x], &method.to_reference(), seed);
        let stack = r.logits.input(0);
        let probs = vote::softmax_mean_flat(stack.flat(), stack.classes());
        (
            vote::argmax(&probs),
            vote::predictive_entropy_flat(stack.flat(), stack.classes()),
        )
    };

    println!("\npredictive entropy under input corruption (50 voters):");
    println!("  {:<22} {:>8} {:>10}", "input", "class", "entropy");
    let x = pool.image(1).to_vec();
    let (class, ent) = entropy_of(x.clone(), 1);
    println!("  {:<22} {class:>8} {ent:>10.3}", "clean");
    // occlude the centre 12x12 patch
    let mut occluded = x.clone();
    for r in 8..20 {
        for c in 8..20 {
            occluded[r * 28 + c] = 0.0;
        }
    }
    let (class, ent) = entropy_of(occluded, 1);
    println!("  {:<22} {class:>8} {ent:>10.3}", "centre occluded");
    // pure noise
    let mut g = XorShift128Plus::new(17);
    let noise: Vec<f32> = (0..784).map(|_| g.next_f32()).collect();
    let (class, ent) = entropy_of(noise, 1);
    println!("  {:<22} {class:>8} {ent:>10.3}", "uniform noise");
    println!("  (entropy should increase top to bottom)");

    // --- 3. Fig 6 curves -------------------------------------------------
    match std::fs::read_to_string(format!("{ARTIFACTS}/fig6.json")) {
        Ok(text) => {
            let v = Json::parse(&text).map_err(bayesdm::util::error::Error::msg)?;
            println!("\nFig 6 (from artifacts/fig6.json):");
            for (ds, curve) in v.get("datasets").and_then(Json::as_obj).unwrap() {
                println!("  {ds}:");
                let nn = curve.get("nn").and_then(Json::as_obj).unwrap();
                let bnn = curve.get("bnn").and_then(Json::as_obj).unwrap();
                let mut ratios: Vec<usize> =
                    nn.keys().filter_map(|k| k.parse().ok()).collect();
                ratios.sort_unstable();
                for r in ratios {
                    let a = nn[&r.to_string()].as_f64().unwrap_or(0.0);
                    let b = bnn[&r.to_string()].as_f64().unwrap_or(0.0);
                    let bar = |v: f64| "#".repeat((v * 30.0) as usize);
                    println!("    ratio {r:>5}  NN  {:>5.1}% {}", 100.0 * a, bar(a));
                    println!("               BNN {:>5.1}% {}", 100.0 * b, bar(b));
                }
            }
        }
        Err(_) => {
            println!("\n(fig6.json not built — run `make fig6` for the accuracy curves)")
        }
    }
    Ok(())
}
