//! Quickstart: bring up the batched reference engine, classify one image
//! with each of the paper's three methods, and show the α-blocked DM
//! dispatch plan plus the uncertainty signal.
//!
//! Runs with **zero artifacts** on the synthetic posterior/dataset; pass
//! an artifact directory (built by `make artifacts`) to use the trained
//! model instead.
//!
//! ```bash
//! cargo run --release --offline --example quickstart [-- ARTIFACTS_DIR]
//! ```

use std::time::Instant;

use bayesdm::coordinator::plan::{InferenceMethod, PlanSummary};
use bayesdm::coordinator::{vote, Engine, EngineConfig};
use bayesdm::dataset::{load_images, load_weights, Dataset, SynthSpec, Synthesizer};
use bayesdm::nn::bnn::BnnModel;
use bayesdm::util::error::Result;
use bayesdm::MNIST_ARCH;

const ALPHA: f64 = 0.1;

/// Trained artifacts when available, the self-contained synthetic pair
/// otherwise.
fn load(artifacts: &str) -> (BnnModel, Dataset, &'static str) {
    let weights = load_weights(format!("{artifacts}/weights_mnist_bnn.bin"));
    let test = load_images(format!("{artifacts}/data_mnist_test.bin"));
    match (weights, test) {
        (Ok(w), Ok(t)) => (BnnModel::new(w), t, "trained artifacts"),
        _ => (
            BnnModel::synthetic(&MNIST_ARCH, 0xBA13_5EED),
            Synthesizer::new(SynthSpec::mnist()).dataset(64),
            "synthetic (pass an artifacts dir for the trained posterior)",
        ),
    }
}

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let (model, test, source) = load(&artifacts);

    // The engine compiles one α-blocked DataflowPlan per method and keeps
    // per-worker scratch arenas across batches (Fig 5's bounded-buffer
    // schedule — results are bit-identical for every α).
    let engine = Engine::new(model, EngineConfig { alpha: ALPHA, ..EngineConfig::default() });
    println!("engine up: {source}, α = {ALPHA}\n");

    let (x, label) = (test.image(0).to_vec(), test.labels[0]);
    println!("classifying test image 0 (true label {label})\n");
    for method in [
        InferenceMethod::Standard { t: 100 },
        InferenceMethod::Hybrid { t: 100 },
        InferenceMethod::paper_dm(ALPHA),
    ] {
        let t0 = Instant::now();
        let r = engine.evaluate_batch_seeded(&[x.clone()], &method.to_reference(), 0xC0FFEE);
        let stack = r.logits.input(0);
        let probs = vote::softmax_mean_flat(stack.flat(), stack.classes());
        let class = vote::argmax(&probs);
        println!(
            "{:<9} voters={:<5} -> class {} (p={:.3}, entropy={:.3} nats) in {:>6.1} ms",
            method.name(),
            stack.voters(),
            class,
            probs[class],
            vote::predictive_entropy_flat(stack.flat(), stack.classes()),
            t0.elapsed().as_secs_f64() * 1e3,
        );
    }

    println!("\nDM-BNN dispatch plan at α = {ALPHA} (same blocks the engine runs):");
    let plan = PlanSummary::build(&MNIST_ARCH, &InferenceMethod::paper_dm(ALPHA), 10);
    for (name, count) in &plan.dispatches {
        println!("  {count:>5} × {name}");
    }
    Ok(())
}
