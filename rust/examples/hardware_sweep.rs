//! Hardware design-space exploration with the 45 nm cost model.
//!
//! Reproduces the paper's hardware evaluation interactively: Table V
//! (three accelerator organizations at α = 0.1), Fig 7 (area vs α), and
//! a traffic breakdown showing *where* the DM energy win comes from
//! (weight-SRAM reads collapse into cheaper β reads + 10× fewer GRNG
//! samples).  The α swept here is the same parameter the inference
//! engine's blocked kernels take (`EngineConfig::alpha` / `--alpha`).
//!
//! ```bash
//! cargo run --release --offline --example hardware_sweep
//! ```

use bayesdm::hwsim::arch::{AcceleratorConfig, Organization};
use bayesdm::hwsim::report::{fig7_rows, render_fig7, render_table5, table5_rows};
use bayesdm::hwsim::sim::{method_for, simulate, traffic_for};
use bayesdm::MNIST_ARCH;

fn main() {
    // Table V (accuracy columns need the quantized functional model; the
    // CLI `tables --table 5` fills them — here the hardware numbers).
    let rows = table5_rows(&[None, None, None]);
    println!("{}", render_table5(&rows));

    // Fig 7: area vs alpha.
    let rows = fig7_rows(&[1.0, 0.8, 0.6, 0.5, 0.4, 0.3, 0.2, 0.1, 0.05]);
    println!("{}", render_fig7(&rows));

    // Where does the energy go?  Traffic breakdown per organization.
    println!("memory traffic per inference (bytes, 8-bit words):");
    println!(
        "  {:<14} {:>12} {:>12} {:>12} {:>12} {:>14}",
        "org", "weight rd", "beta rd", "beta wr", "act rd+wr", "grng samples"
    );
    for org in [Organization::Standard, Organization::Hybrid, Organization::DmBnn] {
        let t = traffic_for(&MNIST_ARCH, &method_for(org));
        println!(
            "  {:<14} {:>12} {:>12} {:>12} {:>12} {:>14}",
            org.name(),
            t.weight_reads,
            t.beta_reads,
            t.beta_writes,
            t.act_reads + t.act_writes,
            t.grng_samples,
        );
    }

    // GRNG-inclusive energy (the paper excludes it "for fairness"; with it
    // included the DM advantage grows — fewer samples, §III-C2).
    println!("\nenergy with GRNG included vs excluded (µJ):");
    for org in [Organization::Standard, Organization::Hybrid, Organization::DmBnn] {
        let cfg = AcceleratorConfig::paper_table5(org);
        let without = simulate(&cfg, false).energy_uj;
        let with = simulate(&cfg, true).energy_uj;
        println!(
            "  {:<14} excl {:>8.1}  incl {:>8.1}  (+{:.1}%)",
            org.name(),
            without,
            with,
            100.0 * (with / without - 1.0)
        );
    }
}
