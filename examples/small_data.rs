//! Small-data & uncertainty: the BNN behaviours Fig 6 and §V-A motivate.
//!
//! Three demonstrations on the served posterior:
//!
//! 1. the shrink-ratio protocol (paper §V-A) on the native synthetic
//!    dataset — how many images survive each ratio;
//! 2. predictive entropy as an uncertainty signal: corrupting an input
//!    (occlusion / noise) must raise the BNN's entropy — the core reason
//!    to pay for Bayesian inference at the edge;
//! 3. the Fig 6 accuracy curves, rendered from `artifacts/fig6.json`
//!    when present (`make fig6`).
//!
//! ```bash
//! cargo run --release --offline --example small_data
//! ```

use anyhow::{Context, Result};

use bayesdm::coordinator::plan::InferenceMethod;
use bayesdm::coordinator::{vote, Executor};
use bayesdm::dataset::{load_images, load_weights, shrink_subset, SynthSpec, Synthesizer};
use bayesdm::runtime::Engine;
use bayesdm::util::Json;

const ARTIFACTS: &str = "artifacts";

fn main() -> Result<()> {
    // --- 1. shrink-ratio protocol on the native generator ----------------
    println!("shrink-ratio protocol (nominal 60000 images, paper §V-A):");
    let mut synth = Synthesizer::new(SynthSpec::mnist());
    let pool = synth.dataset(3000);
    for ratio in [16usize, 64, 256, 1024] {
        let sub = shrink_subset(&pool, ratio, 60_000, 7);
        println!("  ratio {ratio:>5} -> {:>4} images ({} per class)", sub.len(), sub.len() / 10);
    }

    // --- 2. uncertainty under corruption ---------------------------------
    let engine = Engine::new(ARTIFACTS).context("run `make artifacts` first")?;
    let weights = load_weights(format!("{ARTIFACTS}/weights_mnist_bnn.bin"))?;
    let exec = Executor::new(engine, weights, 0x5EED)?;
    let test = load_images(format!("{ARTIFACTS}/data_mnist_test.bin"))?;
    let method = InferenceMethod::Standard { t: 50 };

    println!("\npredictive entropy under input corruption (50 voters):");
    println!("  {:<22} {:>8} {:>10}", "input", "class", "entropy");
    let x = test.image(1).to_vec();
    let logits = exec.evaluate(&x, &method)?;
    println!(
        "  {:<22} {:>8} {:>10.3}",
        "clean",
        vote::argmax(&vote::mean_vote(&logits)),
        vote::predictive_entropy(&logits)
    );
    // occlude the centre 12x12 patch
    let mut occluded = x.clone();
    for r in 8..20 {
        for c in 8..20 {
            occluded[r * 28 + c] = 0.0;
        }
    }
    let logits_o = exec.evaluate(&occluded, &method)?;
    println!(
        "  {:<22} {:>8} {:>10.3}",
        "centre occluded",
        vote::argmax(&vote::mean_vote(&logits_o)),
        vote::predictive_entropy(&logits_o)
    );
    // pure noise
    let mut g = bayesdm::grng::uniform::XorShift128Plus::new(17);
    use bayesdm::grng::uniform::UniformSource;
    let noise: Vec<f32> = (0..784).map(|_| g.next_f32()).collect();
    let logits_n = exec.evaluate(&noise, &method)?;
    println!(
        "  {:<22} {:>8} {:>10.3}",
        "uniform noise",
        vote::argmax(&vote::mean_vote(&logits_n)),
        vote::predictive_entropy(&logits_n)
    );
    println!("  (entropy should increase top to bottom)");

    // --- 3. Fig 6 curves ---------------------------------------------------
    match std::fs::read_to_string(format!("{ARTIFACTS}/fig6.json")) {
        Ok(text) => {
            let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
            println!("\nFig 6 (from artifacts/fig6.json):");
            for (ds, curve) in v.get("datasets").and_then(Json::as_obj).unwrap() {
                println!("  {ds}:");
                let nn = curve.get("nn").and_then(Json::as_obj).unwrap();
                let bnn = curve.get("bnn").and_then(Json::as_obj).unwrap();
                let mut ratios: Vec<usize> = nn.keys().filter_map(|k| k.parse().ok()).collect();
                ratios.sort_unstable();
                for r in ratios {
                    let a = nn[&r.to_string()].as_f64().unwrap_or(0.0);
                    let b = bnn[&r.to_string()].as_f64().unwrap_or(0.0);
                    let bar = |v: f64| "#".repeat((v * 30.0) as usize);
                    println!("    ratio {r:>5}  NN  {:>5.1}% {}", 100.0 * a, bar(a));
                    println!("               BNN {:>5.1}% {}", 100.0 * b, bar(b));
                }
            }
        }
        Err(_) => println!("\n(fig6.json not built — run `make fig6` for the accuracy curves)"),
    }
    Ok(())
}
