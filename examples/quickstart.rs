//! Quickstart: load the artifacts, classify one image with each method,
//! and show the DM plan + uncertainty signal.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example quickstart
//! ```

use anyhow::{Context, Result};

use bayesdm::coordinator::plan::{InferenceMethod, PlanSummary};
use bayesdm::coordinator::{vote, Executor};
use bayesdm::dataset::{load_images, load_weights};
use bayesdm::runtime::Engine;
use bayesdm::MNIST_ARCH;

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());

    // 1. Bring up the engine: PJRT CPU client + AOT artifact manifest.
    let engine = Engine::new(&artifacts).context("run `make artifacts` first")?;
    println!(
        "engine up: {} artifacts, arch {:?}",
        engine.manifest.artifacts.len(),
        engine.manifest.arch
    );

    // 2. Load the trained mean-field posterior and build the executor
    //    (weights are uploaded to the device once, here).
    let weights = load_weights(format!("{artifacts}/weights_mnist_bnn.bin"))?;
    let exec = Executor::new(engine, weights, 0xC0FFEE)?;

    // 3. Grab a test image.
    let test = load_images(format!("{artifacts}/data_mnist_test.bin"))?;
    let (x, label) = (test.image(0), test.labels[0]);
    println!("classifying test image 0 (true label {label})\n");

    // 4. Run all three of the paper's inference methods.
    for method in [
        InferenceMethod::Standard { t: 100 },
        InferenceMethod::Hybrid { t: 100 },
        InferenceMethod::paper_dm(1.0),
    ] {
        let t0 = std::time::Instant::now();
        let logits = exec.evaluate(x, &method)?;
        let probs = vote::softmax_mean(&logits);
        let class = vote::argmax(&probs);
        println!(
            "{:<9} voters={:<5} -> class {} (p={:.3}, entropy={:.3} nats) in {:>6.1} ms",
            method.name(),
            logits.len(),
            class,
            probs[class],
            vote::predictive_entropy(&logits),
            t0.elapsed().as_secs_f64() * 1e3,
        );
    }

    // 5. Show what the DM-BNN plan dispatches under the memory-friendly
    //    α = 0.1 schedule (Fig 5).
    println!("\nDM-BNN dispatch plan at α = 0.1:");
    let plan = PlanSummary::build(&MNIST_ARCH, &InferenceMethod::paper_dm(0.1), 10);
    for (name, count) in &plan.dispatches {
        println!("  {count:>5} × {name}");
    }
    Ok(())
}
