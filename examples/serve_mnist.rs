//! End-to-end serving driver (DESIGN.md §5 "E2E").
//!
//! Boots the router/batcher over the PJRT executor, replays test-set
//! images as classification requests for each of the paper's three
//! methods, and reports accuracy, throughput and latency percentiles —
//! the serving-shape comparison behind EXPERIMENTS.md §E2E.
//!
//! ```bash
//! cargo run --release --offline --example serve_mnist [-- <requests>]
//! ```

use std::time::Instant;

use anyhow::{Context, Result};

use bayesdm::coordinator::plan::InferenceMethod;
use bayesdm::coordinator::{serve, Executor, ServerConfig};
use bayesdm::dataset::{load_images, load_weights};
use bayesdm::runtime::Engine;

const ARTIFACTS: &str = "artifacts";

fn main() -> Result<()> {
    let requests: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("requests must be a number"))
        .unwrap_or(100);

    let test = load_images(format!("{ARTIFACTS}/data_mnist_test.bin"))
        .context("run `make artifacts` first")?;
    let n = requests.min(test.len());

    println!("end-to-end serving driver: {n} requests per method\n");
    println!(
        "{:<10} {:>9} {:>10} {:>10} {:>10} {:>8}",
        "method", "req/s", "p50 (ms)", "p99 (ms)", "voters", "accuracy"
    );

    for method in [
        InferenceMethod::Standard { t: 100 },
        InferenceMethod::Hybrid { t: 100 },
        InferenceMethod::paper_dm(1.0),
        InferenceMethod::paper_dm(0.1),
    ] {
        let label = if let InferenceMethod::DmBnn { alpha, .. } = &method {
            format!("dm a={alpha}")
        } else {
            method.name().to_string()
        };
        let handle = serve(
            || {
                let weights = load_weights(format!("{ARTIFACTS}/weights_mnist_bnn.bin"))?;
                Executor::new(Engine::new(ARTIFACTS)?, weights, 0xE2E)
            },
            ServerConfig { max_batch: 8, workers: 2, ..ServerConfig::default() },
        );
        let t0 = Instant::now();
        let mut pending = Vec::with_capacity(n);
        for i in 0..n {
            pending.push((
                test.labels[i],
                handle
                    .classify(test.image(i).to_vec(), method.clone())
                    .map_err(anyhow::Error::msg)?,
            ));
        }
        let mut correct = 0usize;
        let mut voters = 0usize;
        for (lbl, p) in pending {
            let r = p.wait().map_err(anyhow::Error::msg)?;
            voters = r.voters;
            if r.class == lbl as usize {
                correct += 1;
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        let s = handle.metrics.summary();
        println!(
            "{:<10} {:>9.2} {:>10.1} {:>10.1} {:>10} {:>7.1}%",
            label,
            n as f64 / dt,
            s.p50_us.unwrap_or(0) as f64 / 1e3,
            s.p99_us.unwrap_or(0) as f64 / 1e3,
            voters,
            100.0 * correct as f64 / n as f64,
        );
        handle.shutdown();
    }
    println!("\n(paper Table V shape: DM ≈ 4× faster than standard at equal+ voters)");
    Ok(())
}
